//! Problem instances: assignment and (discrete) optimal transport, plus the
//! §4 θ-scaling that turns an OT instance into an integer-mass transport
//! instance solvable by the unbalanced matching algorithm.

use crate::core::cost::CostMatrix;
use crate::core::error::{OtprError, Result};

/// Assignment instance: n×n costs, every vertex has weight 1/n.
#[derive(Debug, Clone)]
pub struct AssignmentInstance {
    pub costs: CostMatrix,
}

impl AssignmentInstance {
    pub fn new(costs: CostMatrix) -> Result<Self> {
        if costs.na != costs.nb {
            return Err(OtprError::InvalidInstance(format!(
                "assignment requires square costs, got {}x{}",
                costs.nb, costs.na
            )));
        }
        Ok(Self { costs })
    }

    pub fn n(&self) -> usize {
        self.costs.na
    }
}

/// Discrete OT instance: supports A (demand, μ) and B (supply, ν) with
/// probability masses summing to 1 on each side.
#[derive(Debug, Clone)]
pub struct OtInstance {
    pub costs: CostMatrix,
    /// μ_a for each demand point (columns).
    pub demand: Vec<f64>,
    /// ν_b for each supply point (rows).
    pub supply: Vec<f64>,
}

/// Shared marginal validation for every OT-instance representation
/// (dense [`OtInstance::new`] and the implicit `api::ImplicitInstance`):
/// lengths match the cost relation, each side is a probability vector.
pub fn validate_marginals(demand: &[f64], supply: &[f64], na: usize, nb: usize) -> Result<()> {
    if demand.len() != na || supply.len() != nb {
        return Err(OtprError::InvalidInstance("mass dimension mismatch".into()));
    }
    for (name, v) in [("demand", demand), ("supply", supply)] {
        let sum: f64 = v.iter().sum();
        if (sum - 1.0).abs() > 1e-6 {
            return Err(OtprError::InvalidInstance(format!(
                "{name} masses sum to {sum}, expected 1"
            )));
        }
        if v.iter().any(|&x| x < 0.0 || !x.is_finite()) {
            return Err(OtprError::InvalidInstance(format!("negative/NaN {name} mass")));
        }
    }
    Ok(())
}

impl OtInstance {
    pub fn new(costs: CostMatrix, demand: Vec<f64>, supply: Vec<f64>) -> Result<Self> {
        validate_marginals(&demand, &supply, costs.na, costs.nb)?;
        Ok(Self { costs, demand, supply })
    }

    /// Uniform-mass OT instance from an assignment instance.
    pub fn uniform(costs: CostMatrix) -> Result<Self> {
        let na = costs.na;
        let nb = costs.nb;
        Self::new(costs, vec![1.0 / na as f64; na], vec![1.0 / nb as f64; nb])
    }

    pub fn n(&self) -> usize {
        self.costs.na.max(self.costs.nb)
    }
}

/// §4 scaling: multiply masses by θ = 4n/ε, round **demands up** and
/// **supplies down** to integers. Total supply units ≤ θ ≤ total demand
/// units, so the instance is an unbalanced transport problem where all
/// (rounded) supply can be shipped.
#[derive(Debug, Clone)]
pub struct ScaledOtInstance {
    pub theta: f64,
    /// ⌈μ_a·θ⌉ per demand point.
    pub demand_units: Vec<u64>,
    /// ⌊ν_b·θ⌋ per supply point.
    pub supply_units: Vec<u64>,
    /// Supply mass lost to rounding, per b (νb·θ − ⌊νb·θ⌋)/θ; shipped
    /// arbitrarily after the solve so the final plan moves *all* supply.
    pub supply_residual: Vec<f64>,
}

impl ScaledOtInstance {
    pub fn build(inst: &OtInstance, eps: f64) -> Self {
        Self::from_parts(&inst.supply, &inst.demand, inst.n(), eps)
    }

    /// θ-scale raw marginals without an [`OtInstance`] — the entry the
    /// implicit-cost driver uses (masses are O(n) data; no cost slab is
    /// involved in the scaling at all).
    pub fn from_parts(supply: &[f64], demand: &[f64], n: usize, eps: f64) -> Self {
        assert!(eps > 0.0 && eps < 1.0);
        let n = n as f64;
        let theta = 4.0 * n / eps;
        let demand_units: Vec<u64> = demand.iter().map(|&d| (d * theta).ceil() as u64).collect();
        let supply_units: Vec<u64> = supply.iter().map(|&s| (s * theta).floor() as u64).collect();
        let supply_residual: Vec<f64> = supply
            .iter()
            .zip(&supply_units)
            .map(|(&s, &u)| (s * theta - u as f64) / theta)
            .collect();
        Self { theta, demand_units, supply_units, supply_residual }
    }

    pub fn total_supply_units(&self) -> u64 {
        self.supply_units.iter().sum()
    }

    pub fn total_demand_units(&self) -> u64 {
        self.demand_units.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(nb: usize, na: usize) -> CostMatrix {
        CostMatrix::from_fn(nb, na, |b, a| ((b + a) % 3) as f32 / 2.0)
    }

    #[test]
    fn assignment_requires_square() {
        assert!(AssignmentInstance::new(costs(2, 3)).is_err());
        assert_eq!(AssignmentInstance::new(costs(3, 3)).unwrap().n(), 3);
    }

    #[test]
    fn ot_instance_validates_masses() {
        let c = costs(2, 2);
        assert!(OtInstance::new(c.clone(), vec![0.5, 0.5], vec![0.7, 0.3]).is_ok());
        assert!(OtInstance::new(c.clone(), vec![0.5, 0.4], vec![0.7, 0.3]).is_err());
        assert!(OtInstance::new(c.clone(), vec![1.5, -0.5], vec![0.7, 0.3]).is_err());
        assert!(OtInstance::new(c, vec![0.5, 0.5, 0.0], vec![0.7, 0.3]).is_err());
    }

    #[test]
    fn uniform_masses() {
        let i = OtInstance::uniform(costs(4, 4)).unwrap();
        assert!(i.demand.iter().all(|&d| (d - 0.25).abs() < 1e-12));
    }

    #[test]
    fn scaling_directions() {
        let c = costs(2, 2);
        let inst = OtInstance::new(c, vec![0.3, 0.7], vec![0.6, 0.4]).unwrap();
        let s = ScaledOtInstance::build(&inst, 0.1);
        assert!((s.theta - 4.0 * 2.0 / 0.1).abs() < 1e-9);
        // demands up, supplies down
        assert!(s.total_demand_units() as f64 >= s.theta - 1e-9);
        assert!(s.total_supply_units() as f64 <= s.theta + 1e-9);
        assert!(s.total_supply_units() <= s.total_demand_units());
        // residuals small and non-negative
        for &r in &s.supply_residual {
            assert!(r >= -1e-15 && r < 1.0 / s.theta + 1e-15);
        }
    }

    #[test]
    fn residual_mass_bounded_by_eps_quarter() {
        let n = 8;
        let c = costs(n, n);
        let inst = OtInstance::uniform(c).unwrap();
        let eps = 0.2;
        let s = ScaledOtInstance::build(&inst, eps);
        let resid: f64 = s.supply_residual.iter().sum();
        assert!(resid <= eps / 4.0 + 1e-12, "residual {resid} > eps/4");
    }
}
