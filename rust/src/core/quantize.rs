//! ε-unit integer quantization (paper eq. 1).
//!
//! The algorithm transforms every cost into an integer multiple of ε:
//! `c̄(a,b) = ε·⌊c(a,b)/ε⌋`. We store the integer `cq = ⌊c/ε_abs⌋` directly
//! and do *all* dual arithmetic in these integer units, which makes the
//! ε-feasibility conditions (paper eq. 2–3) exact integer identities:
//!
//! ```text
//! y(a)+y(b) ≤ cq(a,b)+1   (a,b) ∉ M
//! y(a)+y(b) = cq(a,b)     (a,b) ∈ M
//! ```
//!
//! `ε_abs = ε · c_max` because the paper assumes costs scaled so the largest
//! equals 1; quantizing relative to the instance's own max reproduces that
//! scaling without mutating the input.
//!
//! **Storage modes.** Dense sources keep the historical in-place `cq`
//! slab (O(n²) i32, byte-identical behavior). Implicit sources
//! ([`crate::core::CostProvider`]) keep **no** per-entry state at all:
//! [`QuantizedCosts::at`] quantizes `provider.cost_at(b, a)` on demand
//! with exactly the dense formula, rows stream through caller scratch
//! ([`QuantizedCosts::fill_row_units`] / [`QuantizedCosts::row_units`]),
//! and the vector backend's block-min cache builds by streaming one f32
//! row at a time ([`QuantizedCosts::build_lane_min_implicit`]) so the
//! only O(n²)-shaped resident state is the O(n²/[`LANES`]) minima. The
//! `epoch` counter bumps on every (re)quantization so row caches
//! ([`crate::core::kernel::arena::RowScratch`]) self-invalidate.

// Kernel-scope lint wall: narrowing casts are confined to the two audited
// sites below (`unit_of`, `max_units`), each range-guarded and annotated.
#![deny(clippy::cast_possible_truncation, clippy::cast_sign_loss)]

use crate::core::cost::CostMatrix;
use crate::core::provider::{CostProvider, CostSource};
use std::fmt;
use std::sync::Arc;

/// Lane width of the vector kernel backend's blocked cost layout. Eight
/// `i32` lanes fill one 256-bit register, so the per-block min reductions
/// in [`QuantizedCosts::build_lane_blocks`] auto-vectorize on stable Rust
/// without any SIMD intrinsics or new dependencies.
pub const LANES: usize = 8;

/// Owned implicit source kept by the quantization so `at`/row streaming
/// work for the arena's whole lifetime (phases, rescales, certificates).
#[derive(Clone)]
pub struct ImplicitSource {
    pub provider: Arc<dyn CostProvider>,
}

impl fmt::Debug for ImplicitSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "ImplicitSource({}, {}x{})",
            self.provider.kind(),
            self.provider.nb(),
            self.provider.na()
        )
    }
}

/// Quantize one raw cost into ε-units — the single formula both storage
/// modes share, which is what makes implicit byte-identical to dense.
#[inline]
#[allow(clippy::cast_possible_truncation)]
fn unit_of(c: f32, inv: f64) -> i32 {
    let q = (f64::from(c) * inv).floor();
    debug_assert!(q >= 0.0 && q <= f64::from(i32::MAX));
    // cast-ok: floored and debug-asserted in [0, i32::MAX]
    q as i32
}

#[derive(Debug, Clone)]
pub struct QuantizedCosts {
    pub nb: usize,
    pub na: usize,
    /// `cq[b*na + a] = ⌊c(b,a)/eps_abs⌋`, row-major, rows = B. **Empty in
    /// implicit mode** — entries quantize on demand from the provider.
    pub cq: Vec<i32>,
    /// The absolute ε used: `eps * c_max` (1.0 fallback when c_max == 0).
    pub eps_abs: f64,
    /// The relative ε requested.
    pub eps: f64,
    /// Max raw cost of the instance (the normalization constant).
    pub c_max: f64,
    /// Bumped on every (re)quantization; row caches key on it.
    pub epoch: u64,
    /// Cached `1.0 / eps_abs` — keeps the implicit per-entry quantize
    /// (`at` on the vector backend's propose hot path) division-free.
    inv_abs: f64,
    implicit: Option<ImplicitSource>,
}

impl QuantizedCosts {
    /// Quantize `costs` at relative precision `eps` ∈ (0, 1).
    pub fn new(costs: &CostMatrix, eps: f64) -> Self {
        let mut q = Self::empty();
        q.requantize(costs, eps);
        q
    }

    /// Quantize either storage mode of a [`CostSource`].
    pub fn from_source(costs: &CostSource<'_>, eps: f64) -> Self {
        let mut q = Self::empty();
        q.requantize_src(costs, eps);
        q
    }

    /// The zero-size placeholder the kernel arena starts from.
    pub fn empty() -> Self {
        Self {
            nb: 0,
            na: 0,
            cq: Vec::new(),
            eps_abs: 1.0,
            eps: 0.5,
            c_max: 0.0,
            epoch: 0,
            inv_abs: 1.0,
            implicit: None,
        }
    }

    /// Re-quantize in place, reusing the existing `cq` allocation — the
    /// [`crate::core::kernel::KernelArena`] reuse path for batched solves
    /// over same-shape instances.
    pub fn requantize(&mut self, costs: &CostMatrix, eps: f64) {
        assert!(eps > 0.0 && eps < 1.0, "eps must be in (0,1), got {eps} (provider=dense)");
        let c_max = costs.max() as f64;
        // All-zero costs: any plan is optimal; pick eps_abs=1 so cq is all 0.
        let eps_abs = if c_max > 0.0 { eps * c_max } else { 1.0 };
        let inv = 1.0 / eps_abs;
        self.cq.clear();
        self.cq.extend(costs.as_slice().iter().map(|&c| unit_of(c, inv)));
        self.nb = costs.nb;
        self.na = costs.na;
        self.eps_abs = eps_abs;
        self.inv_abs = inv;
        self.eps = eps;
        self.c_max = c_max;
        self.implicit = None;
        self.epoch = self.epoch.wrapping_add(1);
    }

    /// Re-target either storage mode: the dense arm is the historical
    /// in-place requantize (byte-identical), the implicit arm re-streams
    /// from the provider instead of re-reading a slab.
    pub fn requantize_src(&mut self, costs: &CostSource<'_>, eps: f64) {
        match costs {
            CostSource::Dense(m) => self.requantize(m, eps),
            CostSource::Implicit(p) => self.requantize_implicit(p.clone(), eps),
        }
    }

    /// Switch to (or re-target) implicit mode: no per-entry state is
    /// materialized — any dense slab from a previous instance is dropped.
    pub fn requantize_implicit(&mut self, provider: Arc<dyn CostProvider>, eps: f64) {
        assert!(
            eps > 0.0 && eps < 1.0,
            "eps must be in (0,1), got {eps} (provider={})",
            provider.kind()
        );
        let c_max = provider.max_cost() as f64;
        let eps_abs = if c_max > 0.0 { eps * c_max } else { 1.0 };
        self.nb = provider.nb();
        self.na = provider.na();
        self.cq = Vec::new();
        self.eps_abs = eps_abs;
        self.inv_abs = 1.0 / eps_abs;
        self.eps = eps;
        self.c_max = c_max;
        self.implicit = Some(ImplicitSource { provider });
        self.epoch = self.epoch.wrapping_add(1);
    }

    /// True when entries quantize on demand from a provider (no `cq` slab).
    #[inline]
    pub fn is_implicit(&self) -> bool {
        self.implicit.is_some()
    }

    /// Storage-mode kind for diagnostics ("dense" or the provider's kind).
    pub fn kind(&self) -> &'static str {
        match &self.implicit {
            None => "dense",
            Some(s) => s.provider.kind(),
        }
    }

    /// Resident per-entry quantized state, in bytes (0 in implicit mode).
    pub fn cost_state_bytes(&self) -> u64 {
        (self.cq.len() * std::mem::size_of::<i32>()) as u64
    }

    #[inline]
    pub fn at(&self, b: usize, a: usize) -> i32 {
        debug_assert!(b < self.nb && a < self.na);
        match &self.implicit {
            None => self.cq[b * self.na + a],
            Some(s) => unit_of(s.provider.cost_at(b, a), self.inv_abs),
        }
    }

    /// Dense row slice. **Dense mode only** — implicit callers stream via
    /// [`QuantizedCosts::row_units`] / [`QuantizedCosts::fill_row_units`].
    #[inline]
    pub fn row(&self, b: usize) -> &[i32] {
        debug_assert!(!self.is_implicit(), "row() needs the dense slab; use row_units()");
        &self.cq[b * self.na..(b + 1) * self.na]
    }

    /// Fill `out` with the quantized units of row `b` (either mode).
    pub fn fill_row_units(&self, b: usize, out: &mut Vec<i32>) {
        out.clear();
        match &self.implicit {
            None => out.extend_from_slice(self.row(b)),
            Some(s) => {
                let inv = self.inv_abs;
                out.extend((0..self.na).map(|a| unit_of(s.provider.cost_at(b, a), inv)));
            }
        }
    }

    /// Row units as a slice: the dense slab directly, or `buf` filled from
    /// the provider — the streaming accessor every O(n²) checker uses so
    /// it never needs more than one row resident.
    pub fn row_units<'a>(&'a self, b: usize, buf: &'a mut Vec<i32>) -> &'a [i32] {
        match &self.implicit {
            None => self.row(b),
            Some(_) => {
                self.fill_row_units(b, &mut *buf);
                &buf[..]
            }
        }
    }

    /// Minimum quantized unit of row `b` (either mode).
    pub fn row_min(&self, b: usize) -> i32 {
        match &self.implicit {
            None => self.row(b).iter().copied().min().unwrap_or(0),
            Some(s) => {
                let inv = self.inv_abs;
                (0..self.na)
                    .map(|a| unit_of(s.provider.cost_at(b, a), inv))
                    .min()
                    .unwrap_or(0)
            }
        }
    }

    /// Rounded-cost value c̄ in original units.
    #[inline]
    pub fn rounded(&self, b: usize, a: usize) -> f64 {
        self.at(b, a) as f64 * self.eps_abs
    }

    /// Upper bound on any quantized entry: costs ≤ c_max ⇒ cq ≤ ⌊1/ε⌋.
    #[allow(clippy::cast_possible_truncation)]
    pub fn max_units(&self) -> i32 {
        // cast-ok: ε ∈ (0, 1) is validated at requantize, bounding ⌊1/ε⌋
        (1.0 / self.eps).floor() as i32
    }

    /// `na` padded up to the vector backend's lane width.
    pub fn na_padded(&self) -> usize {
        self.na.div_ceil(LANES) * LANES
    }

    /// Mirror `cq` into a lane-padded slab (`nb × na_padded`, pad lanes =
    /// `i32::MAX` so they can never look admissible) plus per-row block
    /// minima (`nb × na_padded/LANES`) — the vector kernel's layout. The
    /// propose sweep skips a whole block with one compare against its
    /// minimum, touching 1/[`LANES`] of the memory on non-admissible row
    /// segments. Reuses the caller's allocations across re-quantizations.
    pub fn build_lane_blocks(&self, lane_cq: &mut Vec<i32>, lane_min: &mut Vec<i32>) {
        debug_assert!(!self.is_implicit(), "dense mode only; use build_lane_min_implicit()");
        let na_pad = self.na_padded();
        let nblk = na_pad / LANES;
        lane_cq.clear();
        lane_cq.resize(self.nb * na_pad, i32::MAX);
        lane_min.clear();
        lane_min.resize(self.nb * nblk, i32::MAX);
        for b in 0..self.nb {
            lane_cq[b * na_pad..b * na_pad + self.na].copy_from_slice(self.row(b));
            for blk in 0..nblk {
                let lane = &lane_cq[b * na_pad + blk * LANES..b * na_pad + (blk + 1) * LANES];
                // branchless fixed-width min: one lane-min + horizontal
                // reduce once LLVM unrolls the 8 iterations
                let mut m = lane[0];
                for &v in &lane[1..] {
                    m = if v < m { v } else { m };
                }
                lane_min[b * nblk + blk] = m;
            }
        }
    }

    /// Implicit-mode sibling of [`QuantizedCosts::build_lane_blocks`]:
    /// build **only** the per-row block minima (`nb × na_padded/LANES`) by
    /// streaming one f32 row at a time from the provider — the block-min
    /// cache becomes the only O(n²/[`LANES`])-shaped resident cost state,
    /// and there is no `lane_cq` mirror at all. Minima equal the dense
    /// build's exactly (pad lanes hold `i32::MAX` there and never win).
    pub fn build_lane_min_implicit(&self, lane_min: &mut Vec<i32>) {
        // panic-ok: mode-confusion here is a kernel-internal programming
        // error (the arena picks the build path off is_implicit()), not a
        // caller-reachable state
        let src = self.implicit.as_ref().expect("implicit mode only; use build_lane_blocks()");
        let na_pad = self.na_padded();
        let nblk = na_pad / LANES;
        lane_min.clear();
        lane_min.resize(self.nb * nblk, i32::MAX);
        let inv = self.inv_abs;
        let mut row = vec![0.0f32; self.na];
        for b in 0..self.nb {
            src.provider.fill_row(b, &mut row);
            for (a, &c) in row.iter().enumerate() {
                let v = unit_of(c, inv);
                let m = &mut lane_min[b * nblk + a / LANES];
                if v < *m {
                    *m = v;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantizes_to_floor() {
        // c_max = 1.0 so eps_abs = eps
        let c = CostMatrix::from_vec(1, 4, vec![0.0, 0.09, 0.11, 1.0]).unwrap();
        let q = QuantizedCosts::new(&c, 0.1);
        assert_eq!(q.row(0), &[0, 0, 1, 10]);
        assert!((q.rounded(0, 2) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn rounding_error_below_eps() {
        let c = CostMatrix::from_fn(8, 8, |b, a| ((b * 13 + a * 7) % 11) as f32 / 11.0);
        let q = QuantizedCosts::new(&c, 0.05);
        for b in 0..8 {
            for a in 0..8 {
                let err = c.at(b, a) as f64 - q.rounded(b, a);
                assert!(err >= -1e-9, "rounded above original at ({b},{a})");
                assert!(err < q.eps_abs + 1e-9, "error {err} >= eps_abs {}", q.eps_abs);
            }
        }
    }

    #[test]
    fn normalizes_by_max() {
        // costs up to 20; eps=0.5 relative -> eps_abs = 10
        let c = CostMatrix::from_vec(1, 3, vec![0.0, 9.0, 20.0]).unwrap();
        let q = QuantizedCosts::new(&c, 0.5);
        assert!((q.eps_abs - 10.0).abs() < 1e-9);
        assert_eq!(q.row(0), &[0, 0, 2]);
    }

    #[test]
    fn zero_costs_ok() {
        let c = CostMatrix::zeros(3, 3);
        let q = QuantizedCosts::new(&c, 0.1);
        assert!(q.cq.iter().all(|&x| x == 0));
        assert_eq!(q.eps_abs, 1.0);
    }

    #[test]
    fn max_units_bound_holds() {
        let c = CostMatrix::from_fn(5, 5, |b, a| ((b + a) % 5) as f32 / 4.0);
        let q = QuantizedCosts::new(&c, 0.3);
        let bound = q.max_units();
        assert!(q.cq.iter().all(|&x| x <= bound), "cq exceeds ⌊1/ε⌋ = {bound}");
    }

    #[test]
    #[should_panic]
    fn rejects_bad_eps() {
        let c = CostMatrix::zeros(1, 1);
        let _ = QuantizedCosts::new(&c, 1.5);
    }

    #[test]
    fn lane_blocks_pad_and_min_correctly() {
        // na = 5: one block, lanes 5..8 padded with i32::MAX
        let c = CostMatrix::from_vec(2, 5, vec![0.3, 0.1, 0.9, 0.5, 0.7, 1.0, 0.2, 0.4, 0.6, 0.8])
            .unwrap();
        let q = QuantizedCosts::new(&c, 0.1);
        assert_eq!(q.na_padded(), 8);
        let (mut lane_cq, mut lane_min) = (Vec::new(), Vec::new());
        q.build_lane_blocks(&mut lane_cq, &mut lane_min);
        assert_eq!(lane_cq.len(), 2 * 8);
        assert_eq!(lane_min.len(), 2);
        for b in 0..2 {
            assert_eq!(&lane_cq[b * 8..b * 8 + 5], q.row(b), "real lanes mirror cq");
            assert!(lane_cq[b * 8 + 5..(b + 1) * 8].iter().all(|&v| v == i32::MAX));
            assert_eq!(lane_min[b], *q.row(b).iter().min().unwrap());
        }
        // multiple blocks + allocation reuse across a requantize
        let c = CostMatrix::from_fn(3, 17, |b, a| ((b * 7 + a) % 13) as f32 / 13.0);
        let q2 = QuantizedCosts::new(&c, 0.2);
        q2.build_lane_blocks(&mut lane_cq, &mut lane_min);
        assert_eq!(q2.na_padded(), 24);
        assert_eq!(lane_min.len(), 3 * 3);
        for b in 0..3 {
            for blk in 0..3 {
                let lo = blk * LANES;
                let hi = (lo + LANES).min(17);
                let want = q2.row(b)[lo..hi].iter().copied().min().unwrap();
                assert_eq!(lane_min[b * 3 + blk], want, "b={b} blk={blk}");
            }
        }
    }

    #[test]
    #[allow(clippy::float_cmp)] // eps_abs must replicate exactly, not approximately
    fn implicit_mode_matches_dense_units_without_a_slab() {
        use crate::core::provider::{Costs, GeneratedCosts};
        let dense = CostMatrix::from_fn(5, 13, |b, a| ((b * 7 + a * 5) % 11) as f32 / 10.0);
        let costs = Costs::generated(
            GeneratedCosts::new(5, 13, |b, a| ((b * 7 + a * 5) % 11) as f32 / 10.0).unwrap(),
        );
        let qd = QuantizedCosts::new(&dense, 0.15);
        let qi = QuantizedCosts::from_source(&costs.source(), 0.15);
        assert!(qi.is_implicit() && !qd.is_implicit());
        assert_eq!(qi.kind(), "generated");
        assert_eq!(qi.cost_state_bytes(), 0, "no per-entry state in implicit mode");
        assert!(qd.cost_state_bytes() > 0);
        assert_eq!(qi.eps_abs, qd.eps_abs, "identical normalization");
        let mut buf = Vec::new();
        for b in 0..5 {
            assert_eq!(qi.row_units(b, &mut buf), qd.row(b), "row {b}");
            assert_eq!(qi.row_min(b), qd.row_min(b));
            for a in 0..13 {
                assert_eq!(qi.at(b, a), qd.at(b, a), "({b},{a})");
            }
        }
        // lane minima: implicit streaming build == dense mirror build
        let (mut lane_cq, mut dense_min, mut impl_min) = (Vec::new(), Vec::new(), Vec::new());
        qd.build_lane_blocks(&mut lane_cq, &mut dense_min);
        qi.build_lane_min_implicit(&mut impl_min);
        assert_eq!(impl_min, dense_min);
        // epoch bumps on every requantize (row-cache invalidation key)
        let e0 = qi.epoch;
        let mut qi2 = qi.clone();
        qi2.requantize_src(&costs.source(), 0.1);
        assert_ne!(qi2.epoch, e0);
    }

    #[test]
    #[should_panic]
    fn implicit_rejects_bad_eps_naming_the_provider() {
        use crate::core::provider::GeneratedCosts;
        use std::sync::Arc;
        let g = Arc::new(GeneratedCosts::new(2, 2, |_, _| 0.5).unwrap());
        let mut q = QuantizedCosts::empty();
        q.requantize_implicit(g, 1.5);
    }

    #[test]
    fn requantize_reuses_allocation_and_matches_new() {
        let c1 = CostMatrix::from_vec(1, 4, vec![0.0, 0.09, 0.11, 1.0]).unwrap();
        let c2 = CostMatrix::from_vec(1, 3, vec![0.0, 9.0, 20.0]).unwrap();
        let mut q = QuantizedCosts::new(&c1, 0.1);
        let cap = q.cq.capacity();
        q.requantize(&c2, 0.5);
        assert_eq!(q.cq, QuantizedCosts::new(&c2, 0.5).cq);
        assert!((q.eps_abs - 10.0).abs() < 1e-9);
        assert!(q.cq.capacity() >= 3 && cap >= 3, "allocation reused, not shrunk");
        q.requantize(&c1, 0.1);
        assert_eq!(q.row(0), QuantizedCosts::new(&c1, 0.1).row(0));
    }
}
