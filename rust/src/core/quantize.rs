//! ε-unit integer quantization (paper eq. 1).
//!
//! The algorithm transforms every cost into an integer multiple of ε:
//! `c̄(a,b) = ε·⌊c(a,b)/ε⌋`. We store the integer `cq = ⌊c/ε_abs⌋` directly
//! and do *all* dual arithmetic in these integer units, which makes the
//! ε-feasibility conditions (paper eq. 2–3) exact integer identities:
//!
//! ```text
//! y(a)+y(b) ≤ cq(a,b)+1   (a,b) ∉ M
//! y(a)+y(b) = cq(a,b)     (a,b) ∈ M
//! ```
//!
//! `ε_abs = ε · c_max` because the paper assumes costs scaled so the largest
//! equals 1; quantizing relative to the instance's own max reproduces that
//! scaling without mutating the input.

use crate::core::cost::CostMatrix;

#[derive(Debug, Clone)]
pub struct QuantizedCosts {
    pub nb: usize,
    pub na: usize,
    /// `cq[b*na + a] = ⌊c(b,a)/eps_abs⌋`, row-major, rows = B.
    pub cq: Vec<i32>,
    /// The absolute ε used: `eps * c_max` (1.0 fallback when c_max == 0).
    pub eps_abs: f64,
    /// The relative ε requested.
    pub eps: f64,
    /// Max raw cost of the instance (the normalization constant).
    pub c_max: f64,
}

impl QuantizedCosts {
    /// Quantize `costs` at relative precision `eps` ∈ (0, 1).
    pub fn new(costs: &CostMatrix, eps: f64) -> Self {
        let mut q = Self { nb: 0, na: 0, cq: Vec::new(), eps_abs: 1.0, eps, c_max: 0.0 };
        q.requantize(costs, eps);
        q
    }

    /// Re-quantize in place, reusing the existing `cq` allocation — the
    /// [`crate::core::kernel::KernelArena`] reuse path for batched solves
    /// over same-shape instances.
    pub fn requantize(&mut self, costs: &CostMatrix, eps: f64) {
        assert!(eps > 0.0 && eps < 1.0, "eps must be in (0,1), got {eps}");
        let c_max = costs.max() as f64;
        // All-zero costs: any plan is optimal; pick eps_abs=1 so cq is all 0.
        let eps_abs = if c_max > 0.0 { eps * c_max } else { 1.0 };
        let inv = 1.0 / eps_abs;
        self.cq.clear();
        self.cq.extend(costs.as_slice().iter().map(|&c| {
            let q = (c as f64 * inv).floor();
            debug_assert!(q >= 0.0 && q <= i32::MAX as f64);
            q as i32
        }));
        self.nb = costs.nb;
        self.na = costs.na;
        self.eps_abs = eps_abs;
        self.eps = eps;
        self.c_max = c_max;
    }

    #[inline]
    pub fn at(&self, b: usize, a: usize) -> i32 {
        debug_assert!(b < self.nb && a < self.na);
        self.cq[b * self.na + a]
    }

    #[inline]
    pub fn row(&self, b: usize) -> &[i32] {
        &self.cq[b * self.na..(b + 1) * self.na]
    }

    /// Rounded-cost value c̄ in original units.
    #[inline]
    pub fn rounded(&self, b: usize, a: usize) -> f64 {
        self.at(b, a) as f64 * self.eps_abs
    }

    /// Upper bound on any quantized entry: costs ≤ c_max ⇒ cq ≤ ⌊1/ε⌋.
    pub fn max_units(&self) -> i32 {
        (1.0 / self.eps).floor() as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantizes_to_floor() {
        // c_max = 1.0 so eps_abs = eps
        let c = CostMatrix::from_vec(1, 4, vec![0.0, 0.09, 0.11, 1.0]).unwrap();
        let q = QuantizedCosts::new(&c, 0.1);
        assert_eq!(q.row(0), &[0, 0, 1, 10]);
        assert!((q.rounded(0, 2) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn rounding_error_below_eps() {
        let c = CostMatrix::from_fn(8, 8, |b, a| ((b * 13 + a * 7) % 11) as f32 / 11.0);
        let q = QuantizedCosts::new(&c, 0.05);
        for b in 0..8 {
            for a in 0..8 {
                let err = c.at(b, a) as f64 - q.rounded(b, a);
                assert!(err >= -1e-9, "rounded above original at ({b},{a})");
                assert!(err < q.eps_abs + 1e-9, "error {err} >= eps_abs {}", q.eps_abs);
            }
        }
    }

    #[test]
    fn normalizes_by_max() {
        // costs up to 20; eps=0.5 relative -> eps_abs = 10
        let c = CostMatrix::from_vec(1, 3, vec![0.0, 9.0, 20.0]).unwrap();
        let q = QuantizedCosts::new(&c, 0.5);
        assert!((q.eps_abs - 10.0).abs() < 1e-9);
        assert_eq!(q.row(0), &[0, 0, 2]);
    }

    #[test]
    fn zero_costs_ok() {
        let c = CostMatrix::zeros(3, 3);
        let q = QuantizedCosts::new(&c, 0.1);
        assert!(q.cq.iter().all(|&x| x == 0));
        assert_eq!(q.eps_abs, 1.0);
    }

    #[test]
    fn max_units_bound_holds() {
        let c = CostMatrix::from_fn(5, 5, |b, a| ((b + a) % 5) as f32 / 4.0);
        let q = QuantizedCosts::new(&c, 0.3);
        let bound = q.max_units();
        assert!(q.cq.iter().all(|&x| x <= bound), "cq exceeds ⌊1/ε⌋ = {bound}");
    }

    #[test]
    #[should_panic]
    fn rejects_bad_eps() {
        let c = CostMatrix::zeros(1, 1);
        let _ = QuantizedCosts::new(&c, 1.5);
    }

    #[test]
    fn requantize_reuses_allocation_and_matches_new() {
        let c1 = CostMatrix::from_vec(1, 4, vec![0.0, 0.09, 0.11, 1.0]).unwrap();
        let c2 = CostMatrix::from_vec(1, 3, vec![0.0, 9.0, 20.0]).unwrap();
        let mut q = QuantizedCosts::new(&c1, 0.1);
        let cap = q.cq.capacity();
        q.requantize(&c2, 0.5);
        assert_eq!(q.cq, QuantizedCosts::new(&c2, 0.5).cq);
        assert!((q.eps_abs - 10.0).abs() < 1e-9);
        assert!(q.cq.capacity() >= 3 && cap >= 3, "allocation reused, not shrunk");
        q.requantize(&c1, 0.1);
        assert_eq!(q.row(0), QuantizedCosts::new(&c1, 0.1).row(0));
    }
}
