//! Transport plans σ: A×B → ℝ≥0 (stored (b, a) to match [`CostMatrix`]).
//!
//! Since PR 8 a plan carries one of three representations behind the same
//! API, so the O(n²) slab is an *option*, not an obligation (mirroring
//! what PR 5 did for costs):
//!
//! * `Dense` — the historical row-major `nb·na` slab (Sinkhorn, SSP, XLA
//!   output stays here: those algorithms inherently produce dense
//!   couplings);
//! * `Csr` — the compact support form the push-relabel kernel emits:
//!   `row_ptr`/`col_idx`/`vals` in canonical **(b-ascending, a-ascending)**
//!   order, O(nnz) resident;
//! * `Product` — the lazy product coupling ν⊗μ (`supply`/`demand` only,
//!   O(nb+na) resident), the cancelled-at-phase-0 answer — a dense slab is
//!   materialized only if a caller actually asks for `as_slice()`.
//!
//! Every fold below (`cost`, `cost_with`, marginals, `total_mass`,
//! `support_size`) replicates the dense row-major accumulation order
//! exactly. For CSR this is bit-identical because all stored values and
//! costs are non-negative: every entry the sparse fold skips would have
//! contributed `0.0 · c = +0.0`, and adding `+0.0` to a non-negative
//! accumulator is an IEEE-754 identity. The `Product` folds iterate
//! (b, a) row-major computing `supply[b] · demand[a]` in place — the same
//! arithmetic the old eagerly-materialized product performed.

use crate::core::cost::CostMatrix;
use std::sync::OnceLock;

/// Widen a stored CSR column id to a `usize` index.
#[inline]
fn ai(a: u32) -> usize {
    a as usize // cast-ok: u32→usize is lossless on 32/64-bit targets
}

/// Wire-format constants for [`TransportPlan::to_bytes`]: 4-byte magic,
/// u16 version, u16 reserved, then nb/na/nnz as little-endian u64.
const WIRE_MAGIC: &[u8; 4] = b"OTPL";
const WIRE_VERSION: u16 = 1;
const WIRE_HEADER_BYTES: usize = 4 + 2 + 2 + 8 + 8 + 8;

/// Bounds-checked little-endian cursor for [`TransportPlan::from_bytes`]
/// — every read either yields a value or a sized error, never a panic.
struct WireReader<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> WireReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .at
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| format!("plan bytes truncated at offset {} (need {n})", self.at))?;
        let out = &self.bytes[self.at..end];
        self.at = end;
        Ok(out)
    }

    fn u16(&mut self) -> Result<u16, String> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// A u64 field that must fit in `usize` (dimensions like `na`, and
    /// decoded `row_ptr` entries — both validated later by `from_csr`).
    fn dim_u64(&mut self, what: &str) -> Result<usize, String> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| format!("{what} {v} exceeds usize"))
    }

    /// A u64 *element count* (`nb`, `nnz`): every counted element occupies
    /// at least 4 payload bytes, so any honest count is bounded by the
    /// buffer length — reject forged counts before they size a Vec.
    fn count_u64(&mut self, what: &str) -> Result<usize, String> {
        let n = self.dim_u64(what)?;
        if n > self.bytes.len() {
            return Err(format!(
                "{what} {n} is implausible for a {}-byte payload",
                self.bytes.len()
            ));
        }
        Ok(n)
    }
}

#[derive(Debug, Clone)]
enum Repr {
    /// Row-major `nb·na` slab.
    Dense(Vec<f64>),
    /// Compressed sparse rows in canonical (b-asc, a-asc) order.
    /// `row_ptr.len() == nb + 1`; entries of row `b` live at
    /// `row_ptr[b]..row_ptr[b+1]` with strictly ascending `col_idx`.
    Csr { row_ptr: Vec<usize>, col_idx: Vec<u32>, vals: Vec<f64> },
    /// The product coupling ν⊗μ: entry (b, a) is `supply[b] · demand[a]`,
    /// never stored.
    Product { supply: Vec<f64>, demand: Vec<f64> },
}

#[derive(Debug)]
pub struct TransportPlan {
    pub nb: usize,
    pub na: usize,
    repr: Repr,
    /// Lazily materialized dense view for compact representations —
    /// filled only when a caller insists on [`TransportPlan::as_slice`].
    dense_cache: OnceLock<Vec<f64>>,
}

impl Clone for TransportPlan {
    fn clone(&self) -> Self {
        // The dense cache is a per-instance convenience, not state: a
        // clone of a compact plan stays compact (O(nnz) clone cost).
        Self { nb: self.nb, na: self.na, repr: self.repr.clone(), dense_cache: OnceLock::new() }
    }
}

impl TransportPlan {
    pub fn zeros(nb: usize, na: usize) -> Self {
        Self { nb, na, repr: Repr::Dense(vec![0.0; nb * na]), dense_cache: OnceLock::new() }
    }

    /// The product coupling ν⊗μ — always feasible for probability
    /// marginals. The one plan every layer returns for a solve stopped
    /// at phase 0 (see `api::adapter` and the kernel drivers), so the
    /// cancelled-answer shape is defined in exactly one place. Lazy: the
    /// plan holds only the two marginal vectors (O(nb+na) bytes); the
    /// n² slab exists only if someone calls [`TransportPlan::as_slice`].
    pub fn product(supply: &[f64], demand: &[f64]) -> Self {
        Self {
            nb: supply.len(),
            na: demand.len(),
            repr: Repr::Product { supply: supply.to_vec(), demand: demand.to_vec() },
            dense_cache: OnceLock::new(),
        }
    }

    // CONTRACT: sparse extraction order == dense fold order — rows must
    // arrive b-ascending with strictly a-ascending columns, or every
    // bit-identity claim between this plan and its dense twin breaks.
    /// Build a plan directly in CSR form. Validates the canonical order
    /// (b-ascending rows, strictly a-ascending columns), bounds, and that
    /// every value is finite and non-negative — the preconditions the
    /// bit-identical fold replication relies on.
    pub fn from_csr(
        nb: usize,
        na: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        vals: Vec<f64>,
    ) -> Result<Self, String> {
        if row_ptr.len() != nb + 1 {
            return Err(format!("row_ptr len {} != nb + 1 = {}", row_ptr.len(), nb + 1));
        }
        if row_ptr[0] != 0 || *row_ptr.last().unwrap_or(&0) != col_idx.len() {
            return Err("row_ptr must start at 0 and end at nnz".into());
        }
        if col_idx.len() != vals.len() {
            return Err(format!("col_idx len {} != vals len {}", col_idx.len(), vals.len()));
        }
        for b in 0..nb {
            let (lo, hi) = (row_ptr[b], row_ptr[b + 1]);
            if lo > hi || hi > col_idx.len() {
                return Err(format!("row_ptr not monotone at row {b}"));
            }
            let mut prev: Option<u32> = None;
            for i in lo..hi {
                let a = col_idx[i];
                if ai(a) >= na {
                    return Err(format!("col {a} out of bounds (na={na}) in row {b}"));
                }
                if prev.is_some_and(|p| p >= a) {
                    return Err(format!("columns not strictly ascending in row {b}"));
                }
                prev = Some(a);
                let v = vals[i];
                if !v.is_finite() || v < 0.0 {
                    return Err(format!("value {v} at ({b},{a}) is not finite non-negative"));
                }
            }
        }
        let repr = Repr::Csr { row_ptr, col_idx, vals };
        Ok(Self { nb, na, repr, dense_cache: OnceLock::new() })
    }

    /// Serialize a CSR plan into the compact versioned wire format:
    /// magic `OTPL`, u16 version, u16 reserved, then `nb`/`na`/`nnz` as
    /// little-endian u64 followed by the raw `row_ptr` (u64), `col_idx`
    /// (u32), and `vals` (f64 bit patterns) arrays. Values round-trip
    /// bit-for-bit, so a shipped plan folds identically to the original
    /// (the CONTRACT above). Dense and product reprs return `None`: the
    /// wire format carries exactly the canonical sparse form — callers
    /// holding a dense slab keep it local or extract CSR first.
    pub fn to_bytes(&self) -> Option<Vec<u8>> {
        let (row_ptr, col_idx, vals) = self.csr_view()?;
        let mut out = Vec::with_capacity(
            WIRE_HEADER_BYTES + row_ptr.len() * 8 + col_idx.len() * 4 + vals.len() * 8,
        );
        out.extend_from_slice(WIRE_MAGIC);
        out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        // cast-ok: usize → u64 is lossless on every supported target
        out.extend_from_slice(&(self.nb as u64).to_le_bytes());
        out.extend_from_slice(&(self.na as u64).to_le_bytes());
        out.extend_from_slice(&(vals.len() as u64).to_le_bytes());
        for &p in row_ptr {
            out.extend_from_slice(&(p as u64).to_le_bytes());
        }
        for &c in col_idx {
            out.extend_from_slice(&c.to_le_bytes());
        }
        for &v in vals {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        Some(out)
    }

    /// Parse the compact wire format back into a CSR plan. The decoded
    /// triplet is handed to [`TransportPlan::from_csr`], so every
    /// canonical-form invariant (monotone `row_ptr`, strictly ascending
    /// columns, bounds, finite non-negative values) is re-validated —
    /// bytes from an untrusted peer cannot construct a malformed plan.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, String> {
        let mut r = WireReader { bytes, at: 0 };
        let magic = r.take(4)?;
        if magic != WIRE_MAGIC {
            return Err(format!("bad plan magic {magic:?} (want {WIRE_MAGIC:?})"));
        }
        let version = r.u16()?;
        if version != WIRE_VERSION {
            return Err(format!("unsupported plan wire version {version} (have {WIRE_VERSION})"));
        }
        let _reserved = r.u16()?;
        let nb = r.count_u64("nb")?;
        let na = r.dim_u64("na")?;
        let nnz = r.count_u64("nnz")?;
        let rows = nb.checked_add(1).ok_or_else(|| "nb overflows".to_string())?;
        let mut row_ptr = Vec::with_capacity(rows);
        for _ in 0..rows {
            row_ptr.push(r.dim_u64("row_ptr entry")?);
        }
        let mut col_idx = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            col_idx.push(r.u32()?);
        }
        let mut vals = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            vals.push(f64::from_bits(r.u64()?));
        }
        if r.at != bytes.len() {
            return Err(format!("{} trailing bytes after plan payload", bytes.len() - r.at));
        }
        // CONTRACT: sparse extraction order == dense fold order — decoded
        // bytes go back through from_csr so the canonical order is proven,
        // not assumed, before any fold replicates it.
        Self::from_csr(nb, na, row_ptr, col_idx, vals)
    }

    /// Which representation the plan currently holds — for diagnostics
    /// and memory accounting (`"dense"`, `"csr"`, or `"product"`).
    pub fn repr_kind(&self) -> &'static str {
        match &self.repr {
            Repr::Dense(_) => "dense",
            Repr::Csr { .. } => "csr",
            Repr::Product { .. } => "product",
        }
    }

    /// The CSR triplet when the plan is in sparse form (`None` otherwise).
    pub fn csr_view(&self) -> Option<(&[usize], &[u32], &[f64])> {
        match &self.repr {
            Repr::Csr { row_ptr, col_idx, vals } => Some((row_ptr, col_idx, vals)),
            _ => None,
        }
    }

    /// Resident bytes of the plan's representation (plus the lazy dense
    /// cache if a caller forced it): O(n²)·8 dense, O(nnz) CSR,
    /// O(nb+na) product. This is what `SolveStats::plan_state_bytes`
    /// reports — the plan-side counterpart of `cost_state_bytes`.
    pub fn state_bytes(&self) -> u64 {
        let repr = match &self.repr {
            Repr::Dense(flow) => flow.len() * 8,
            Repr::Csr { row_ptr, col_idx, vals } => {
                row_ptr.len() * 8 + col_idx.len() * 4 + vals.len() * 8
            }
            Repr::Product { supply, demand } => (supply.len() + demand.len()) * 8,
        };
        let cache = self.dense_cache.get().map_or(0, |c| c.len() * 8);
        (repr + cache) as u64
    }

    /// Materialize the dense row-major slab for the current repr.
    fn materialized(&self) -> Vec<f64> {
        match &self.repr {
            Repr::Dense(flow) => flow.clone(),
            Repr::Csr { row_ptr, col_idx, vals } => {
                let mut flow = vec![0.0; self.nb * self.na];
                for b in 0..self.nb {
                    for i in row_ptr[b]..row_ptr[b + 1] {
                        flow[b * self.na + ai(col_idx[i])] = vals[i];
                    }
                }
                flow
            }
            Repr::Product { supply, demand } => {
                let mut flow = vec![0.0; self.nb * self.na];
                for (b, &s) in supply.iter().enumerate() {
                    for (a, &d) in demand.iter().enumerate() {
                        flow[b * self.na + a] = s * d;
                    }
                }
                flow
            }
        }
    }

    /// Switch a compact representation to the dense slab in place
    /// (mutation entry points only — readers stay compact).
    fn ensure_dense(&mut self) {
        if matches!(self.repr, Repr::Dense(_)) {
            return;
        }
        let flow = self.materialized();
        self.repr = Repr::Dense(flow);
        self.dense_cache = OnceLock::new();
    }

    #[inline]
    pub fn at(&self, b: usize, a: usize) -> f64 {
        match &self.repr {
            Repr::Dense(flow) => flow[b * self.na + a],
            Repr::Csr { row_ptr, col_idx, vals } => {
                let row = &col_idx[row_ptr[b]..row_ptr[b + 1]];
                // cast-ok: stored columns are < na which fits u32 (checked
                // at construction), so probing with a truncated too-large
                // `a` could only miss — and callers pass a < na anyway
                match row.binary_search(&(a as u32)) {
                    Ok(i) => vals[row_ptr[b] + i],
                    Err(_) => 0.0,
                }
            }
            Repr::Product { supply, demand } => supply[b] * demand[a],
        }
    }

    /// Mutating writes densify a compact plan first — the builder API for
    /// the inherently-dense solvers (Sinkhorn, SSP, XLA). The kernel
    /// drivers never call these; they assemble CSR directly.
    #[inline]
    pub fn add(&mut self, b: usize, a: usize, amount: f64) {
        self.ensure_dense();
        if let Repr::Dense(flow) = &mut self.repr {
            flow[b * self.na + a] += amount;
        }
    }

    pub fn set(&mut self, b: usize, a: usize, amount: f64) {
        self.ensure_dense();
        if let Repr::Dense(flow) = &mut self.repr {
            flow[b * self.na + a] = amount;
        }
    }

    /// Dense row-major view. **Materializes** a compact representation on
    /// first call (cached for the plan's lifetime) — prefer the fold
    /// methods below, which stay O(nnz) on sparse plans.
    pub fn as_slice(&self) -> &[f64] {
        match &self.repr {
            Repr::Dense(flow) => flow,
            _ => self.dense_cache.get_or_init(|| self.materialized()),
        }
    }

    /// Transport cost Σ σ(b,a)·c(b,a) — row-major fold, O(nnz) on CSR.
    pub fn cost(&self, costs: &CostMatrix) -> f64 {
        self.cost_with(|b, a| costs.at(b, a) as f64)
    }

    /// The cost fold against an arbitrary per-entry cost function — how
    /// implicit [`crate::core::provider::CostSource`]s price a plan
    /// without a slab. Replicates the dense row-major fold order per
    /// representation (CSR skips only exact-`+0.0` terms).
    pub fn cost_with<F: FnMut(usize, usize) -> f64>(&self, mut cost: F) -> f64 {
        match &self.repr {
            Repr::Dense(flow) => {
                let mut sum = 0.0;
                for b in 0..self.nb {
                    for a in 0..self.na {
                        sum += flow[b * self.na + a] * cost(b, a);
                    }
                }
                sum
            }
            Repr::Csr { row_ptr, col_idx, vals } => {
                let mut sum = 0.0;
                for b in 0..self.nb {
                    for i in row_ptr[b]..row_ptr[b + 1] {
                        sum += vals[i] * cost(b, ai(col_idx[i]));
                    }
                }
                sum
            }
            Repr::Product { supply, demand } => {
                let mut sum = 0.0;
                for (b, &s) in supply.iter().enumerate() {
                    for (a, &d) in demand.iter().enumerate() {
                        sum += (s * d) * cost(b, a);
                    }
                }
                sum
            }
        }
    }

    /// Row sums: total mass shipped out of each supply b.
    pub fn supply_marginal(&self) -> Vec<f64> {
        match &self.repr {
            Repr::Dense(flow) => (0..self.nb)
                .map(|b| flow[b * self.na..(b + 1) * self.na].iter().sum())
                .collect(),
            Repr::Csr { row_ptr, vals, .. } => (0..self.nb)
                .map(|b| vals[row_ptr[b]..row_ptr[b + 1]].iter().sum())
                .collect(),
            Repr::Product { supply, demand } => supply
                .iter()
                .map(|&s| demand.iter().map(|&d| s * d).sum())
                .collect(),
        }
    }

    /// Column sums: total mass received by each demand a (accumulated in
    /// b-ascending order, matching the dense fold).
    pub fn demand_marginal(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.na];
        match &self.repr {
            Repr::Dense(flow) => {
                for b in 0..self.nb {
                    for (a, o) in out.iter_mut().enumerate() {
                        *o += flow[b * self.na + a];
                    }
                }
            }
            Repr::Csr { row_ptr, col_idx, vals } => {
                for b in 0..self.nb {
                    for i in row_ptr[b]..row_ptr[b + 1] {
                        out[ai(col_idx[i])] += vals[i];
                    }
                }
            }
            Repr::Product { supply, demand } => {
                for &s in supply {
                    for (o, &d) in out.iter_mut().zip(demand) {
                        *o += s * d;
                    }
                }
            }
        }
        out
    }

    /// Total mass moved.
    pub fn total_mass(&self) -> f64 {
        match &self.repr {
            Repr::Dense(flow) => flow.iter().sum(),
            Repr::Csr { vals, .. } => vals.iter().sum(),
            Repr::Product { supply, demand } => supply
                .iter()
                .map(|&s| demand.iter().map(|&d| s * d).sum::<f64>())
                .sum(),
        }
    }

    /// Number of non-zero entries — the paper advertises a *compact* plan
    /// (≤ na+nb−1 support for vertex-form solutions).
    pub fn support_size(&self) -> usize {
        match &self.repr {
            Repr::Dense(flow) => flow.iter().filter(|&&f| f > 0.0).count(),
            Repr::Csr { vals, .. } => vals.iter().filter(|&&f| f > 0.0).count(),
            Repr::Product { supply, demand } => supply
                .iter()
                .map(|&s| demand.iter().filter(|&&d| s * d > 0.0).count())
                .sum(),
        }
    }

    /// Check the plan is a valid transport plan for (supply, demand):
    /// non-negative, marginals within `tol` of bounds, all supply moved.
    /// O(nnz + nb + na) on CSR plans.
    pub fn check(&self, supply: &[f64], demand: &[f64], tol: f64) -> Result<(), String> {
        if supply.len() != self.nb || demand.len() != self.na {
            return Err("marginal dimension mismatch".into());
        }
        let negative = match &self.repr {
            Repr::Dense(flow) => flow.iter().any(|&f| f < -tol),
            // zero entries outside the support can never fall below -tol
            // (tol ≥ 0 for every caller), so scanning the values suffices
            Repr::Csr { vals, .. } => vals.iter().any(|&f| f < -tol),
            Repr::Product { supply: s, demand: d } => {
                s.iter().any(|&sv| d.iter().any(|&dv| sv * dv < -tol))
            }
        };
        if negative {
            return Err("negative flow".into());
        }
        for (b, (&got, &want)) in self.supply_marginal().iter().zip(supply).enumerate() {
            if got > want + tol {
                return Err(format!("supply {b} overshipped: {got} > {want}"));
            }
            if got < want - tol {
                return Err(format!("supply {b} not fully shipped: {got} < {want}"));
            }
        }
        for (a, (&got, &want)) in self.demand_marginal().iter().zip(demand).enumerate() {
            if got > want + tol {
                return Err(format!("demand {a} overfilled: {got} > {want}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marginals_and_cost() {
        let mut p = TransportPlan::zeros(2, 2);
        p.add(0, 0, 0.25);
        p.add(0, 1, 0.25);
        p.add(1, 1, 0.5);
        assert_eq!(p.supply_marginal(), vec![0.5, 0.5]);
        assert_eq!(p.demand_marginal(), vec![0.25, 0.75]);
        assert!((p.total_mass() - 1.0).abs() < 1e-12);
        assert_eq!(p.support_size(), 3);
        let c = CostMatrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]).unwrap();
        // 0.25·c(0,0)=0 + 0.25·c(0,1)=0.25 + 0.5·c(1,1)=0
        assert!((p.cost(&c) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn check_accepts_valid_plan() {
        let mut p = TransportPlan::zeros(2, 2);
        p.add(0, 0, 0.5);
        p.add(1, 1, 0.5);
        p.check(&[0.5, 0.5], &[0.5, 0.5], 1e-9).unwrap();
    }

    #[test]
    fn check_rejects_undershipment() {
        let mut p = TransportPlan::zeros(2, 2);
        p.add(0, 0, 0.3);
        let err = p.check(&[0.5, 0.5], &[0.5, 0.5], 1e-9).unwrap_err();
        assert!(err.contains("not fully shipped"), "{err}");
    }

    #[test]
    fn check_rejects_overfill() {
        let mut p = TransportPlan::zeros(1, 1);
        p.add(0, 0, 2.0);
        assert!(p.check(&[2.0], &[1.0], 1e-9).is_err());
    }

    #[test]
    fn csr_plan_matches_its_dense_twin_bit_for_bit() {
        // same plan, both representations, every fold identical
        let sparse = TransportPlan::from_csr(
            3,
            3,
            vec![0, 2, 3, 5],
            vec![0, 2, 1, 0, 2],
            vec![0.125, 0.25, 0.375, 0.125, 0.125],
        )
        .unwrap();
        let mut dense = TransportPlan::zeros(3, 3);
        for b in 0..3 {
            for a in 0..3 {
                dense.set(b, a, sparse.at(b, a));
            }
        }
        let c = CostMatrix::from_fn(3, 3, |b, a| ((b * 3 + a) % 4) as f32 / 4.0);
        assert_eq!(sparse.cost(&c).to_bits(), dense.cost(&c).to_bits());
        assert_eq!(sparse.supply_marginal(), dense.supply_marginal());
        assert_eq!(sparse.demand_marginal(), dense.demand_marginal());
        assert_eq!(sparse.total_mass().to_bits(), dense.total_mass().to_bits());
        assert_eq!(sparse.support_size(), dense.support_size());
        assert_eq!(sparse.as_slice(), dense.as_slice());
        assert_eq!(sparse.repr_kind(), "csr");
        assert_eq!(dense.repr_kind(), "dense");
        assert!(sparse.state_bytes() < 3 * 3 * 8, "CSR without the forced cache stays compact");
    }

    #[test]
    fn from_csr_rejects_malformed_input() {
        // unsorted columns
        assert!(TransportPlan::from_csr(1, 3, vec![0, 2], vec![2, 1], vec![0.5, 0.5]).is_err());
        // duplicate columns
        assert!(TransportPlan::from_csr(1, 3, vec![0, 2], vec![1, 1], vec![0.5, 0.5]).is_err());
        // column out of bounds
        assert!(TransportPlan::from_csr(1, 2, vec![0, 1], vec![2], vec![0.5]).is_err());
        // row_ptr shape
        assert!(TransportPlan::from_csr(2, 2, vec![0, 1], vec![0], vec![0.5]).is_err());
        // negative value
        assert!(TransportPlan::from_csr(1, 2, vec![0, 1], vec![0], vec![-0.5]).is_err());
        // valid empty row is fine
        let p = TransportPlan::from_csr(2, 2, vec![0, 0, 1], vec![1], vec![1.0]).unwrap();
        assert_eq!(p.at(0, 1), 0.0);
        assert_eq!(p.at(1, 1), 1.0);
    }

    #[test]
    fn product_plan_is_lazy_and_exact() {
        let supply = vec![0.25, 0.75];
        let demand = vec![0.5, 0.25, 0.25];
        let p = TransportPlan::product(&supply, &demand);
        assert_eq!(p.repr_kind(), "product");
        assert_eq!(p.state_bytes(), (2 + 3) * 8, "O(nb+na) before any dense access");
        assert!((p.total_mass() - 1.0).abs() < 1e-12);
        p.check(&supply, &demand, 1e-12).unwrap();
        // eager twin for the bit-identity check
        let mut dense = TransportPlan::zeros(2, 3);
        for (b, &s) in supply.iter().enumerate() {
            for (a, &d) in demand.iter().enumerate() {
                dense.set(b, a, s * d);
            }
        }
        let c = CostMatrix::from_fn(2, 3, |b, a| (b + a) as f32 / 4.0);
        assert_eq!(p.cost(&c).to_bits(), dense.cost(&c).to_bits());
        assert_eq!(p.supply_marginal(), dense.supply_marginal());
        assert_eq!(p.demand_marginal(), dense.demand_marginal());
        // as_slice materializes (and is counted by state_bytes thereafter)
        assert_eq!(p.as_slice(), dense.as_slice());
        assert!(p.state_bytes() >= (2 * 3) * 8);
    }

    #[test]
    fn mutation_densifies_compact_reprs() {
        let mut p = TransportPlan::from_csr(2, 2, vec![0, 1, 2], vec![0, 1], vec![0.5, 0.5])
            .unwrap();
        p.add(0, 1, 0.25);
        assert_eq!(p.repr_kind(), "dense");
        assert!((p.at(0, 1) - 0.25).abs() < 1e-15);
        assert!((p.at(0, 0) - 0.5).abs() < 1e-15);
        let mut q = TransportPlan::product(&[1.0], &[1.0]);
        q.set(0, 0, 0.5);
        assert_eq!(q.repr_kind(), "dense");
        assert!((q.total_mass() - 0.5).abs() < 1e-15);
    }

    #[test]
    fn clone_keeps_compact_representation() {
        let p = TransportPlan::from_csr(1, 2, vec![0, 1], vec![1], vec![1.0]).unwrap();
        let _ = p.as_slice(); // force the cache on the original
        let q = p.clone();
        assert_eq!(q.repr_kind(), "csr");
        assert_eq!(q.state_bytes(), 2 * 8 + 4 + 8, "clone drops the dense cache");
    }

    #[test]
    fn wire_format_round_trips_random_csr_plans_bit_for_bit() {
        crate::util::proptest_mini::check_default("csr wire round-trip", |rng| {
            let nb = 1 + rng.next_below(12) as usize;
            let na = 1 + rng.next_below(12) as usize;
            let mut row_ptr = vec![0usize];
            let mut col_idx: Vec<u32> = Vec::new();
            let mut vals: Vec<f64> = Vec::new();
            for _ in 0..nb {
                // random strictly-ascending column subset for this row
                for a in 0..na {
                    if rng.next_f64() < 0.4 {
                        col_idx.push(a as u32);
                        vals.push(rng.uniform(0.0, 2.0));
                    }
                }
                row_ptr.push(col_idx.len());
            }
            let plan = TransportPlan::from_csr(nb, na, row_ptr, col_idx, vals)
                .map_err(|e| format!("generator produced invalid CSR: {e}"))?;
            let bytes = plan.to_bytes().ok_or("CSR plan must serialize")?;
            let back = TransportPlan::from_bytes(&bytes).map_err(|e| format!("decode: {e}"))?;
            crate::prop_assert!(back.repr_kind() == "csr", "decoded repr {}", back.repr_kind());
            let (rp0, ci0, v0) = plan.csr_view().unwrap();
            let (rp1, ci1, v1) = back.csr_view().unwrap();
            crate::prop_assert!(rp0 == rp1, "row_ptr changed across the wire");
            crate::prop_assert!(ci0 == ci1, "col_idx changed across the wire");
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            crate::prop_assert!(bits(v0) == bits(v1), "values changed bit patterns");
            Ok(())
        });
    }

    #[test]
    fn wire_format_rejects_malformed_bytes() {
        let plan =
            TransportPlan::from_csr(2, 2, vec![0, 1, 2], vec![0, 1], vec![0.5, 0.5]).unwrap();
        let bytes = plan.to_bytes().unwrap();

        // truncation anywhere fails cleanly
        for cut in [0, 3, 7, bytes.len() / 2, bytes.len() - 1] {
            assert!(TransportPlan::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // bad magic
        let mut b = bytes.clone();
        b[0] = b'X';
        assert!(TransportPlan::from_bytes(&b).is_err());
        // unknown version
        let mut b = bytes.clone();
        b[4] = 9;
        assert!(TransportPlan::from_bytes(&b).is_err());
        // trailing garbage
        let mut b = bytes.clone();
        b.push(0);
        assert!(TransportPlan::from_bytes(&b).is_err());
        // decoded payloads re-run from_csr validation: flip a value's sign
        // bit so it decodes as a negative flow
        let mut b = bytes;
        let last = b.len() - 1;
        b[last] |= 0x80;
        let err = TransportPlan::from_bytes(&b).unwrap_err();
        assert!(err.contains("finite non-negative"), "got: {err}");

        // non-CSR reprs have no wire form
        assert!(TransportPlan::zeros(2, 2).to_bytes().is_none());
        assert!(TransportPlan::product(&[1.0], &[1.0]).to_bytes().is_none());
    }
}
