//! Transport plans σ: A×B → ℝ≥0 (stored (b, a) to match [`CostMatrix`]).

use crate::core::cost::CostMatrix;

#[derive(Debug, Clone)]
pub struct TransportPlan {
    pub nb: usize,
    pub na: usize,
    flow: Vec<f64>,
}

impl TransportPlan {
    pub fn zeros(nb: usize, na: usize) -> Self {
        Self { nb, na, flow: vec![0.0; nb * na] }
    }

    /// The product coupling ν⊗μ — always feasible for probability
    /// marginals. The one plan every layer returns for a solve stopped
    /// at phase 0 (see `api::adapter` and the kernel drivers), so the
    /// cancelled-answer shape is defined in exactly one place.
    pub fn product(supply: &[f64], demand: &[f64]) -> Self {
        let (nb, na) = (supply.len(), demand.len());
        let mut plan = Self::zeros(nb, na);
        for (b, &s) in supply.iter().enumerate() {
            for (a, &d) in demand.iter().enumerate() {
                plan.set(b, a, s * d);
            }
        }
        plan
    }

    #[inline]
    pub fn at(&self, b: usize, a: usize) -> f64 {
        self.flow[b * self.na + a]
    }

    #[inline]
    pub fn add(&mut self, b: usize, a: usize, amount: f64) {
        self.flow[b * self.na + a] += amount;
    }

    pub fn set(&mut self, b: usize, a: usize, amount: f64) {
        self.flow[b * self.na + a] = amount;
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.flow
    }

    /// Transport cost Σ σ(b,a)·c(b,a).
    pub fn cost(&self, costs: &CostMatrix) -> f64 {
        self.flow
            .iter()
            .zip(costs.as_slice())
            .map(|(&f, &c)| f * c as f64)
            .sum()
    }

    /// Row sums: total mass shipped out of each supply b.
    pub fn supply_marginal(&self) -> Vec<f64> {
        (0..self.nb)
            .map(|b| self.flow[b * self.na..(b + 1) * self.na].iter().sum())
            .collect()
    }

    /// Column sums: total mass received by each demand a.
    pub fn demand_marginal(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.na];
        for b in 0..self.nb {
            for a in 0..self.na {
                out[a] += self.at(b, a);
            }
        }
        out
    }

    /// Total mass moved.
    pub fn total_mass(&self) -> f64 {
        self.flow.iter().sum()
    }

    /// Number of non-zero entries — the paper advertises a *compact* plan
    /// (≤ na+nb−1 support for vertex-form solutions).
    pub fn support_size(&self) -> usize {
        self.flow.iter().filter(|&&f| f > 0.0).count()
    }

    /// Check the plan is a valid transport plan for (supply, demand):
    /// non-negative, marginals within `tol` of bounds, all supply moved.
    pub fn check(&self, supply: &[f64], demand: &[f64], tol: f64) -> Result<(), String> {
        if supply.len() != self.nb || demand.len() != self.na {
            return Err("marginal dimension mismatch".into());
        }
        if self.flow.iter().any(|&f| f < -tol) {
            return Err("negative flow".into());
        }
        for (b, (&got, &want)) in self.supply_marginal().iter().zip(supply).enumerate() {
            if got > want + tol {
                return Err(format!("supply {b} overshipped: {got} > {want}"));
            }
            if got < want - tol {
                return Err(format!("supply {b} not fully shipped: {got} < {want}"));
            }
        }
        for (a, (&got, &want)) in self.demand_marginal().iter().zip(demand).enumerate() {
            if got > want + tol {
                return Err(format!("demand {a} overfilled: {got} > {want}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marginals_and_cost() {
        let mut p = TransportPlan::zeros(2, 2);
        p.add(0, 0, 0.25);
        p.add(0, 1, 0.25);
        p.add(1, 1, 0.5);
        assert_eq!(p.supply_marginal(), vec![0.5, 0.5]);
        assert_eq!(p.demand_marginal(), vec![0.25, 0.75]);
        assert!((p.total_mass() - 1.0).abs() < 1e-12);
        assert_eq!(p.support_size(), 3);
        let c = CostMatrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]).unwrap();
        // 0.25·c(0,0)=0 + 0.25·c(0,1)=0.25 + 0.5·c(1,1)=0
        assert!((p.cost(&c) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn check_accepts_valid_plan() {
        let mut p = TransportPlan::zeros(2, 2);
        p.add(0, 0, 0.5);
        p.add(1, 1, 0.5);
        p.check(&[0.5, 0.5], &[0.5, 0.5], 1e-9).unwrap();
    }

    #[test]
    fn check_rejects_undershipment() {
        let mut p = TransportPlan::zeros(2, 2);
        p.add(0, 0, 0.3);
        let err = p.check(&[0.5, 0.5], &[0.5, 0.5], 1e-9).unwrap_err();
        assert!(err.contains("not fully shipped"), "{err}");
    }

    #[test]
    fn check_rejects_overfill() {
        let mut p = TransportPlan::zeros(1, 1);
        p.add(0, 0, 2.0);
        assert!(p.check(&[2.0], &[1.0], 1e-9).is_err());
    }
}
