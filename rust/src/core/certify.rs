//! Solution certification: every [`crate::api::Solution`] — matching or
//! transport plan — can be verified against its instance without trusting
//! the solver that produced it.
//!
//! The paper's advantage over Sinkhorn is that push-relabel "readily
//! provides … a solution to an approximate version of the dual
//! formulation": the ε-unit duals the engines already maintain are a
//! *checkable certificate* of the additive guarantee. This module turns
//! that into a typed [`Certificate`] with three independent verdicts:
//!
//! * **primal** — the coupling is structurally valid (perfect + mirror
//!   consistent for matchings; marginals within the §4 unit-rounding
//!   tolerance for plans) and the reported cost matches the coupling;
//! * **dual** — the exported duals are ε-feasible *post-completion*: the
//!   relaxed condition `y(a)+y(b) ≤ cq(a,b)+1` on **every** edge plus the
//!   sign invariants. (Condition (3) equality and the free-vertex rules of
//!   [`crate::core::duals::check_feasible`] hold only mid-algorithm —
//!   arbitrary completion legitimately breaks them, while the relaxed form
//!   survives and is exactly what the lower bound needs.)
//! * **gap** — `cost ≤ dual_lower_bound + ε·U`, the additive guarantee as
//!   an inequality between two numbers the checker computed itself.
//!
//! The dual lower bounds are Lemma 3.1 and its transport generalization:
//! any y with `y(a)+y(b) ≤ cq+1` everywhere gives, for assignment,
//! `OPT ≥ (Σy − n)·ε_abs` (sum (2) over the optimal matching's n edges),
//! and for OT the LP-feasible potentials `α_a = (y(a)−1)·ε_abs`,
//! `β_b = y(b)·ε_abs` give `OPT ≥ Σ μ_a α_a + Σ ν_b β_b`.
//!
//! `U` is the total-cost scale of the answer shape: `n·c_max` for a
//! matching (n edges), `c_max` for a plan (unit total mass).
//!
//! Consumers: `SolveRequest::certify(true)` (the registry attaches a
//! certificate post-solve), the coordinator's audit sampling
//! ([`crate::coordinator::metrics::Metrics::record_audit`]), the
//! `exp/conformance.rs` golden-corpus runner, and `otpr certify`.
//!
//! Layering note: this core module deliberately takes `api::Solution` /
//! `api::SolveRequest` at its entry point — the certificate's contract is
//! "any answer the public surface can return is checkable", and the
//! request is the only faithful source of the eps semantics the engines
//! solved under. The per-shape checkers below it stay on pure core types.

use crate::api::problem::{Coupling, Problem, Solution};
use crate::api::request::SolveRequest;
use crate::core::duals::{dual_lower_bound_units, DualWeights};
use crate::core::instance::{AssignmentInstance, OtInstance};
use crate::core::matching::Matching;
use crate::core::provider::CostSource;
use crate::core::quantize::QuantizedCosts;
use crate::core::transport::TransportPlan;
use crate::util::minijson::{obj, Json};

/// Slack applied to the `gap ≤ bound` comparison (float accumulation).
pub const GAP_TOL: f64 = 1e-9;

/// Upper bounds of the gap/bound-ratio histogram buckets shared by the
/// coordinator audit metrics and the conformance report. A healthy engine
/// keeps its mass at small ratios; anything beyond the `1.0` bucket is a
/// broken guarantee.
pub const GAP_RATIO_BUCKETS: [f64; 6] = [0.1, 0.25, 0.5, 0.75, 1.0, f64::INFINITY];

/// Bucket index for a certified gap against its bound. A zero bound (e.g.
/// all-zero costs, or an exact engine) maps a zero gap to the first bucket
/// and anything positive to the overflow bucket.
pub fn gap_ratio_bucket(gap: f64, bound: f64) -> usize {
    let ratio = if bound > 0.0 {
        gap / bound
    } else if gap <= GAP_TOL {
        0.0
    } else {
        f64::INFINITY
    };
    GAP_RATIO_BUCKETS
        .iter()
        .position(|&ub| ratio <= ub)
        .unwrap_or(GAP_RATIO_BUCKETS.len() - 1)
}

/// Outcome of certifying one solution against its instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Certificate {
    /// Coupling is feasible and the reported cost matches it.
    pub primal_ok: bool,
    /// Exported duals are ε-feasible; `None` when the engine ships none
    /// (Sinkhorn, exact oracles, greedy, device-resident runs).
    pub dual_ok: Option<bool>,
    /// `cost − dual_lower_bound` in original cost units; `None` without a
    /// usable dual certificate.
    pub gap: Option<f64>,
    /// The certified lower bound on the true optimum.
    pub dual_lower_bound: Option<f64>,
    /// Additive budget `ε·U` the gap must stay within.
    pub bound: f64,
    /// The solution's reported cost (denormalized for convenience).
    pub cost: f64,
    /// First violation found, human-readable (units *and* dequantized).
    pub detail: Option<String>,
}

impl Certificate {
    fn failed(cost: f64, detail: String) -> Self {
        Self {
            primal_ok: false,
            dual_ok: None,
            gap: None,
            dual_lower_bound: None,
            bound: 0.0,
            cost,
            detail: Some(detail),
        }
    }

    /// `gap ≤ bound` (vacuously true without a dual certificate).
    pub fn gap_ok(&self) -> bool {
        match self.gap {
            Some(g) => g <= self.bound + GAP_TOL,
            None => true,
        }
    }

    /// Everything that *could* be checked passed.
    pub fn ok(&self) -> bool {
        self.primal_ok && self.dual_ok != Some(false) && self.gap_ok()
    }

    /// One-line report for CLI/log output.
    pub fn summary(&self) -> String {
        let dual = match self.dual_ok {
            Some(true) => "ok",
            Some(false) => "FAIL",
            None => "n/a",
        };
        let gap = match self.gap {
            Some(g) => format!("{g:.6}"),
            None => "n/a".to_string(),
        };
        let mut s = format!(
            "primal={} dual={dual} gap={gap} bound={:.6} [{}]",
            if self.primal_ok { "ok" } else { "FAIL" },
            self.bound,
            if self.ok() { "OK" } else { "FAIL" }
        );
        if let Some(d) = &self.detail {
            s.push_str(&format!(" — {d}"));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        obj(vec![
            ("primal_ok", Json::Bool(self.primal_ok)),
            (
                "dual_ok",
                self.dual_ok.map(Json::Bool).unwrap_or(Json::Null),
            ),
            ("gap", opt(self.gap)),
            ("dual_lower_bound", opt(self.dual_lower_bound)),
            ("bound", Json::Num(self.bound)),
            ("cost", Json::Num(self.cost)),
            ("ok", Json::Bool(self.ok())),
        ])
    }
}

/// Certify `sol` as an answer to `problem` under the request it was solved
/// with. The request supplies the accuracy target (and its semantics), from
/// which the checker rebuilds the exact quantization the engines used —
/// certifying with a different `eps` than the solve ran at reports
/// `dual_ok = Some(false)` rather than a false pass, because the integer
/// feasibility identities only hold in the solver's own units.
pub fn certify(problem: &Problem, sol: &Solution, req: &SolveRequest) -> Certificate {
    // Degraded answers (deadline-pressured warm ladders stopping at a
    // level boundary) are feasible in the *achieved* level's quantization,
    // not the requested one — certify against what the solve actually
    // delivered, which the caller can read back from the certificate and
    // `Solution::degraded_eps_param`.
    let adjusted;
    let req = match sol.degraded_eps_param() {
        Some(p) if p > 0.0 => {
            adjusted = degraded_request(sol, req, p);
            &adjusted
        }
        _ => req,
    };
    match (&sol.coupling, problem) {
        (Coupling::Matching(m), Problem::Assignment(inst)) => {
            certify_matching(inst, m, sol.duals.as_ref(), sol.cost, req)
        }
        // Implicit instances certify by streaming rows from the provider —
        // the checker itself never materializes the O(n²) slab either.
        (Coupling::Matching(m), Problem::Implicit(inst)) if inst.masses.is_none() => {
            certify_matching_src(&inst.costs.source(), m, sol.duals.as_ref(), sol.cost, req)
        }
        (Coupling::Matching(_), _) => Certificate::failed(
            sol.cost,
            "matching coupling cannot answer an OT problem".to_string(),
        ),
        (Coupling::Plan(p), Problem::Implicit(inst)) => {
            let src = inst.costs.source();
            match &inst.masses {
                Some((supply, demand)) => {
                    certify_plan_src(&src, supply, demand, p, sol.duals.as_ref(), sol.cost, req.eps)
                }
                // plan answer to an implicit assignment problem: certify
                // against the uniform-mass relaxation, streamed
                None => {
                    let (nb, na) = (src.nb(), src.na());
                    let supply = vec![1.0 / nb as f64; nb];
                    let demand = vec![1.0 / na as f64; na];
                    certify_plan_src(
                        &src,
                        &supply,
                        &demand,
                        p,
                        sol.duals.as_ref(),
                        sol.cost,
                        req.eps,
                    )
                }
            }
        }
        // Plans answer both kinds: an assignment problem answered by an OT
        // engine is certified against its uniform-mass relaxation (whose
        // optimum equals the assignment optimum / n, by Birkhoff).
        (Coupling::Plan(p), _) => match problem.to_ot_instance() {
            Ok(ot) => certify_plan(&ot, p, sol.duals.as_ref(), sol.cost, req.eps),
            Err(e) => Certificate::failed(sol.cost, e.to_string()),
        },
    }
}

/// The request a degraded answer actually satisfies. Matching answers
/// carry the 3·ε_param·n·c_max guarantee at the achieved level's ε_param
/// (raw semantics). Plan answers ran θ at the original eps_mass and
/// terminated their matching phase at ε_match = `eps_param`; since the
/// ladder only coarsens (ε_match ≥ eps/6), the overall OT guarantee
/// `eps_mass/2 + 3·ε_match ≤ 6·ε_match` holds, and the plan checker's
/// quantization `eps/6` lands back on the achieved ε_match.
fn degraded_request(sol: &Solution, req: &SolveRequest, eps_param: f64) -> SolveRequest {
    let mut r = req.clone();
    match &sol.coupling {
        Coupling::Matching(_) => {
            r.eps = eps_param;
            r.eps_semantics = crate::api::request::EpsSemantics::AlgorithmParam;
        }
        Coupling::Plan(_) => {
            r.eps = 6.0 * eps_param;
            r.eps_semantics = crate::api::request::EpsSemantics::Overall;
        }
    }
    r
}

fn certify_matching(
    inst: &AssignmentInstance,
    m: &Matching,
    duals: Option<&DualWeights>,
    cost: f64,
    req: &SolveRequest,
) -> Certificate {
    certify_matching_src(&CostSource::Dense(&inst.costs), m, duals, cost, req)
}

fn certify_matching_src(
    src: &CostSource<'_>,
    m: &Matching,
    duals: Option<&DualWeights>,
    cost: f64,
    req: &SolveRequest,
) -> Certificate {
    let n = src.na();
    let c_max = src.max_cost() as f64;
    // The assignment engines run the core at `eps_param` and guarantee
    // 3·ε_param·n·c_max (rounding + feasibility + completion) — which is
    // eps·n·c_max for Overall-semantics requests.
    let eps_param = req.eps_param(3.0);
    let bound = 3.0 * eps_param * n as f64 * c_max;
    let mut detail: Option<String> = None;

    let primal_ok = match check_matching_primal(src, m, cost) {
        Ok(()) => true,
        Err(e) => {
            detail = Some(e);
            false
        }
    };

    let (dual_ok, gap, lb) = match duals {
        None => (None, None, None),
        Some(y) => {
            if !(eps_param > 0.0 && eps_param < 1.0) {
                if detail.is_none() {
                    detail = Some(format!(
                        "eps parameter {eps_param} outside (0,1): cannot rebuild the quantization"
                    ));
                }
                (Some(false), None, None)
            } else {
                let q = QuantizedCosts::from_source(src, eps_param);
                match check_matching_duals(&q, y) {
                    Err(e) => {
                        if detail.is_none() {
                            detail = Some(e);
                        }
                        (Some(false), None, None)
                    }
                    Ok(()) => {
                        let lb = dual_lower_bound_units(y) as f64 * q.eps_abs;
                        (Some(true), Some(cost - lb), Some(lb))
                    }
                }
            }
        }
    };

    Certificate { primal_ok, dual_ok, gap, dual_lower_bound: lb, bound, cost, detail }
}

fn check_matching_primal(src: &CostSource<'_>, m: &Matching, cost: f64) -> Result<(), String> {
    if m.nb() != src.nb() || m.na() != src.na() {
        return Err(format!(
            "matching dimensions {}x{} do not fit the {}x{} instance",
            m.nb(),
            m.na(),
            src.nb(),
            src.na()
        ));
    }
    m.check_consistent()?;
    if !m.is_perfect() {
        return Err(format!("matching not perfect: {} free supply vertices", m.free_b().len()));
    }
    let recomputed = src.matching_cost(m);
    if (recomputed - cost).abs() > 1e-6 * cost.abs().max(1.0) {
        return Err(format!("reported cost {cost} != recomputed matching cost {recomputed}"));
    }
    Ok(())
}

/// Relaxed ε-feasibility a *finished* assignment solution must satisfy:
/// signs, `y(a)+y(b) ≤ cq+1` on every edge (matched edges pass through
/// condition (3) equality; arbitrary completion edges pass because (2)
/// held for them while unmatched and duals froze at termination), and the
/// Lemma 3.2 magnitude bound.
fn check_matching_duals(q: &QuantizedCosts, y: &DualWeights) -> Result<(), String> {
    if y.yb.len() != q.nb || y.ya.len() != q.na {
        return Err(format!(
            "dual dimensions ({}, {}) do not fit the {}x{} quantization",
            y.yb.len(),
            y.ya.len(),
            q.nb,
            q.na
        ));
    }
    check_signs(y)?;
    check_relaxed_feasibility(q, y)?;
    let bound = (1.0 / q.eps).ceil() as i32 + 2;
    for &v in y.ya.iter().chain(y.yb.iter()) {
        if v.abs() > bound {
            return Err(format!(
                "Lemma 3.2 violated: |y| = {} units > {bound} units ({:.6} > {:.6} dequantized)",
                v.abs(),
                v.abs() as f64 * q.eps_abs,
                bound as f64 * q.eps_abs
            ));
        }
    }
    Ok(())
}

fn certify_plan(
    ot: &OtInstance,
    plan: &TransportPlan,
    duals: Option<&DualWeights>,
    cost: f64,
    eps: f64,
) -> Certificate {
    certify_plan_src(
        &CostSource::Dense(&ot.costs),
        &ot.supply,
        &ot.demand,
        plan,
        duals,
        cost,
        eps,
    )
}

fn certify_plan_src(
    src: &CostSource<'_>,
    supply: &[f64],
    demand: &[f64],
    plan: &TransportPlan,
    duals: Option<&DualWeights>,
    cost: f64,
    eps: f64,
) -> Certificate {
    let c_max = src.max_cost() as f64;
    // Unit total mass ⇒ the additive target is ε·c_max (Theorem 4.2 /
    // AWR'17 parameterization alike).
    let bound = eps * c_max;
    let n = src.nb().max(src.na()) as f64;
    let mut detail: Option<String> = None;

    // §4 mass scaling rounds at θ = 4n/ε, so demand marginals may
    // legitimately overshoot by up to 2/θ = ε/(2n) per vertex; 1e-6 floors
    // the tolerance for exact and Sinkhorn-rounded plans at eps → 0.
    let tol = if eps > 0.0 { (eps / (2.0 * n)).max(1e-6) } else { 1e-6 };
    let primal_ok = match check_plan_primal(src, supply, demand, plan, cost, tol) {
        Ok(()) => true,
        Err(e) => {
            detail = Some(e);
            false
        }
    };

    // The OT engines quantize costs at the §4 split ε_match = ε/6.
    let eps_match = eps / 6.0;
    let (dual_ok, gap, lb) = match duals {
        None => (None, None, None),
        Some(y) => {
            if !(eps_match > 0.0 && eps_match < 1.0) {
                if detail.is_none() {
                    detail = Some(format!(
                        "eps parameter {eps_match} outside (0,1): cannot rebuild the quantization"
                    ));
                }
                (Some(false), None, None)
            } else {
                let q = QuantizedCosts::from_source(src, eps_match);
                match check_plan_duals(&q, y) {
                    Err(e) => {
                        if detail.is_none() {
                            detail = Some(e);
                        }
                        (Some(false), None, None)
                    }
                    Ok(()) => {
                        let lb = ot_dual_lower_bound(&q, y, demand, supply);
                        (Some(true), Some(cost - lb), Some(lb))
                    }
                }
            }
        }
    };

    Certificate { primal_ok, dual_ok, gap, dual_lower_bound: lb, bound, cost, detail }
}

/// Primal side of the plan certificate: dimensions, feasibility
/// (`TransportPlan::check`), and cost recomputation. All three stream
/// over the plan's own representation — O(nnz) work and no dense
/// materialization for the kernel engines' CSR plans — while the cost
/// fold prices entries through the [`CostSource`] row streams, so an
/// implicit instance certifies without a cost slab either. (The dual
/// side below still streams full rows via `QuantizedCosts::from_source`:
/// dual feasibility is a statement about *every* edge, not the support.)
fn check_plan_primal(
    src: &CostSource<'_>,
    supply: &[f64],
    demand: &[f64],
    plan: &TransportPlan,
    cost: f64,
    tol: f64,
) -> Result<(), String> {
    if plan.nb != src.nb() || plan.na != src.na() {
        return Err(format!(
            "plan dimensions {}x{} do not fit the {}x{} instance",
            plan.nb,
            plan.na,
            src.nb(),
            src.na()
        ));
    }
    plan.check(supply, demand, tol)?;
    let recomputed = src.plan_cost(plan);
    if (recomputed - cost).abs() > 1e-6 * cost.abs().max(1.0) {
        return Err(format!("reported cost {cost} != recomputed plan cost {recomputed}"));
    }
    Ok(())
}

/// Generalized dual feasibility for OT solutions: the per-vertex duals
/// exported by the §4 solver (max copy dual per vertex — well-defined by
/// the free-copies-at-max invariant and Lemma 4.1) must satisfy the signs
/// and the relaxed condition on every edge of the *unbalanced* instance.
fn check_plan_duals(q: &QuantizedCosts, y: &DualWeights) -> Result<(), String> {
    if y.yb.len() != q.nb || y.ya.len() != q.na {
        return Err(format!(
            "dual dimensions ({}, {}) do not fit the {}x{} quantization",
            y.yb.len(),
            y.ya.len(),
            q.nb,
            q.na
        ));
    }
    check_signs(y)?;
    check_relaxed_feasibility(q, y)
}

fn check_signs(y: &DualWeights) -> Result<(), String> {
    for (b, &yb) in y.yb.iter().enumerate() {
        if yb < 0 {
            return Err(format!("sign invariant violated: y(b={b}) = {yb} units < 0"));
        }
    }
    for (a, &ya) in y.ya.iter().enumerate() {
        if ya > 0 {
            return Err(format!("sign invariant violated: y(a={a}) = {ya} units > 0"));
        }
    }
    Ok(())
}

/// `y(a)+y(b) ≤ cq(a,b)+1` on every edge — the one condition both coupling
/// shapes need for their lower bound, reported with units *and*
/// dequantized values so failing seeds are debuggable.
fn check_relaxed_feasibility(q: &QuantizedCosts, y: &DualWeights) -> Result<(), String> {
    // rows stream through one scratch buffer so implicit quantizations
    // certify without a resident slab
    let mut rowbuf: Vec<i32> = Vec::new();
    for b in 0..q.nb {
        let yb = y.yb[b];
        let row = q.row_units(b, &mut rowbuf);
        for (a, &cq) in row.iter().enumerate() {
            let sum = y.ya[a] + yb;
            if sum > cq + 1 {
                return Err(format!(
                    "relaxed feasibility violated on edge (b={b},a={a}): \
                     y(a)+y(b) = {sum} units > cq+1 = {} units \
                     (dequantized: {:.6} > {:.6}, eps_abs = {:.3e}, provider={})",
                    cq + 1,
                    sum as f64 * q.eps_abs,
                    (cq + 1) as f64 * q.eps_abs,
                    q.eps_abs,
                    q.kind()
                ));
            }
        }
    }
    Ok(())
}

/// Transport dual objective of the LP-feasible potentials induced by an
/// edge-feasible y: `α_a = (y(a)−1)·ε_abs`, `β_b = y(b)·ε_abs` satisfy
/// `α_a + β_b ≤ (cq+1−1)·ε_abs = c̄ ≤ c`, so weak duality gives
/// `OPT ≥ Σ μ_a α_a + Σ ν_b β_b = ε_abs·(Σ μ_a y(a) + Σ ν_b y(b) − 1)`.
fn ot_dual_lower_bound(
    q: &QuantizedCosts,
    y: &DualWeights,
    demand: &[f64],
    supply: &[f64],
) -> f64 {
    let da: f64 = demand.iter().zip(&y.ya).map(|(&mu, &ya)| mu * ya as f64).sum();
    let sb: f64 = supply.iter().zip(&y.yb).map(|(&nu, &yb)| nu * yb as f64).sum();
    q.eps_abs * (da + sb - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::adapter::{NativeSeqSolver, SinkhornSolver, Solver};
    use crate::api::problem::Problem;
    use crate::api::request::SolveRequest;
    use crate::data::workloads::Workload;

    fn assignment(n: usize, seed: u64) -> Problem {
        Problem::Assignment(Workload::RandomCosts { n }.assignment(seed))
    }

    #[test]
    fn push_relabel_assignment_certifies() {
        let p = assignment(16, 1);
        let req = SolveRequest::new(0.3);
        let sol = NativeSeqSolver { paranoid: true, warm_levels: 0 }.solve(&p, &req).unwrap();
        let cert = certify(&p, &sol, &req);
        assert!(cert.primal_ok, "{:?}", cert.detail);
        assert_eq!(cert.dual_ok, Some(true), "{:?}", cert.detail);
        assert!(cert.gap_ok(), "gap {:?} > bound {}", cert.gap, cert.bound);
        assert!(cert.ok());
        assert!(cert.dual_lower_bound.unwrap() <= cert.cost + GAP_TOL);
    }

    #[test]
    fn ot_push_relabel_certifies_with_duals() {
        let p = Problem::Ot(Workload::Fig1 { n: 12 }.ot_with_random_masses(3));
        let req = SolveRequest::new(0.25);
        let sol = NativeSeqSolver { paranoid: true, warm_levels: 0 }.solve(&p, &req).unwrap();
        let cert = certify(&p, &sol, &req);
        assert!(cert.primal_ok, "{:?}", cert.detail);
        assert_eq!(cert.dual_ok, Some(true), "{:?}", cert.detail);
        assert!(cert.gap_ok(), "gap {:?} > bound {}", cert.gap, cert.bound);
    }

    #[test]
    fn sinkhorn_reports_no_dual_verdict() {
        let p = Problem::Ot(Workload::Fig1 { n: 10 }.ot_with_random_masses(5));
        let req = SolveRequest::new(0.25);
        let sol = SinkhornSolver { log_domain: true, max_iters: 100_000 }
            .solve(&p, &req)
            .unwrap();
        let cert = certify(&p, &sol, &req);
        assert!(cert.primal_ok, "{:?}", cert.detail);
        assert_eq!(cert.dual_ok, None);
        assert_eq!(cert.gap, None);
        assert!(cert.gap_ok() && cert.ok());
    }

    #[test]
    fn corrupted_matching_fails_primal() {
        let p = assignment(10, 2);
        let req = SolveRequest::new(0.3);
        let mut sol = NativeSeqSolver { paranoid: false, warm_levels: 0 }.solve(&p, &req).unwrap();
        if let crate::api::problem::Coupling::Matching(m) = &mut sol.coupling {
            m.unlink_b(0);
        }
        let cert = certify(&p, &sol, &req);
        assert!(!cert.primal_ok);
        assert!(!cert.ok());
        assert!(cert.detail.unwrap().contains("not perfect"));
    }

    #[test]
    fn corrupted_duals_fail_with_both_units_and_dequantized() {
        let p = assignment(10, 3);
        let req = SolveRequest::new(0.3);
        let mut sol = NativeSeqSolver { paranoid: false, warm_levels: 0 }.solve(&p, &req).unwrap();
        sol.duals.as_mut().unwrap().yb[0] = 1_000;
        let cert = certify(&p, &sol, &req);
        assert_eq!(cert.dual_ok, Some(false));
        assert!(!cert.ok());
        let msg = cert.detail.unwrap();
        assert!(msg.contains("units"), "{msg}");
        assert!(msg.contains("dequantized"), "{msg}");
    }

    #[test]
    fn wrong_cost_fails_primal() {
        let p = assignment(8, 4);
        let req = SolveRequest::new(0.3);
        let mut sol = NativeSeqSolver { paranoid: false, warm_levels: 0 }.solve(&p, &req).unwrap();
        sol.cost += 1.0;
        let cert = certify(&p, &sol, &req);
        assert!(!cert.primal_ok);
        assert!(cert.detail.unwrap().contains("recomputed"));
    }

    #[test]
    fn gap_ratio_buckets_cover_edge_cases() {
        assert_eq!(gap_ratio_bucket(0.0, 1.0), 0);
        assert_eq!(gap_ratio_bucket(0.5, 1.0), 2);
        assert_eq!(gap_ratio_bucket(1.0, 1.0), 4);
        assert_eq!(gap_ratio_bucket(2.0, 1.0), 5);
        assert_eq!(gap_ratio_bucket(0.0, 0.0), 0);
        assert_eq!(gap_ratio_bucket(0.5, 0.0), 5);
    }

    #[test]
    fn json_round_trips() {
        let p = assignment(6, 6);
        let req = SolveRequest::new(0.4);
        let sol = NativeSeqSolver { paranoid: false, warm_levels: 0 }.solve(&p, &req).unwrap();
        let cert = certify(&p, &sol, &req);
        let j = cert.to_json();
        assert_eq!(j.get("ok").unwrap(), &Json::Bool(true));
        assert!(j.get("gap").unwrap().as_f64().is_some());
        assert!(Json::parse(&j.to_string()).is_ok());
        assert!(cert.summary().contains("primal=ok"));
    }
}
