//! Core domain types: cost matrices, ε-quantization, matchings, duals,
//! transport plans, problem instances, and the invariant checkers that the
//! test-suite and `otpr validate` use to certify solver output.

pub mod certify;
pub mod control;
pub mod cost;
pub mod duals;
pub mod error;
pub mod instance;
pub mod kernel;
pub mod matching;
pub mod provider;
pub mod quantize;
pub mod transport;

pub use certify::{certify, Certificate};
pub use kernel::{ChunkedKernel, FlowKernel, KernelArena, KernelPhase, ScalarKernel};
pub use control::{CancelToken, Progress, ProgressFn, SolveControl, CANCELLED_NOTE};
pub use cost::CostMatrix;
pub use duals::DualWeights;
pub use error::{OtprError, Result};
pub use instance::{AssignmentInstance, OtInstance, ScaledOtInstance};
pub use matching::{Matching, FREE};
pub use provider::{
    CostProvider, CostSource, Costs, DenseCosts, GeneratedCosts, L1PointCosts, SqEuclideanCosts,
};
pub use quantize::QuantizedCosts;
pub use transport::TransportPlan;
