//! Solver-facing control primitives: cancellation, deadlines, progress.
//!
//! These sit in `core` (not `api`) so the algorithm layer can honor
//! cancellation and report progress without depending on the public API
//! layer above it. [`crate::api::SolveRequest`] is the caller-facing
//! builder that snapshots into a [`SolveControl`].

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Note appended to [`crate::solvers::SolveStats::notes`] when a solve was
/// stopped early by cancellation or budget exhaustion.
pub const CANCELLED_NOTE: &str = "cancelled";

/// Note prefix recording that a deadline-pressured warm-ladder solve
/// stopped at a level boundary and returned the last *completed* level's
/// answer: `degraded_eps_param=<ε>` where `<ε>` is the matching-quantization
/// parameter the returned state is actually feasible for. Unlike
/// [`CANCELLED_NOTE`], a degraded answer still carries the paper's additive
/// guarantee — just at the coarser ε — and certifies against it
/// ([`crate::core::certify::certify`] is degraded-aware).
pub const DEGRADED_NOTE_PREFIX: &str = "degraded_eps_param=";

/// Shared cancellation flag. Clone freely; all clones observe `cancel()`.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// One progress event, emitted after each completed phase (push-relabel) or
/// stopping-rule check (Sinkhorn).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Progress {
    /// Phase (or iteration) number, 1-based.
    pub phase: usize,
    /// Free mass remaining, in the engine's natural unit: free supply
    /// vertices (assignment), free supply units (OT push-relabel), or the
    /// current marginal violation (Sinkhorn).
    pub free: f64,
}

/// Observer callback; shared so a request can fan out to worker threads.
pub type ProgressFn = Arc<dyn Fn(Progress) + Send + Sync>;

/// Solver-facing cancellation + progress handle. Solvers poll
/// [`SolveControl::should_stop`] between phases and stream
/// (phase, free-mass) events through [`SolveControl::report`].
#[derive(Clone, Default)]
pub struct SolveControl {
    pub(crate) cancel: Option<CancelToken>,
    pub(crate) deadline: Option<Instant>,
    pub(crate) observer: Option<ProgressFn>,
    /// When set, warm-ladder drivers treat the deadline as a *degrade*
    /// signal at level boundaries (return the last completed level's
    /// certified coarser-ε answer) instead of cancelling mid-ladder.
    /// Explicit token cancellation always cancels.
    pub(crate) degrade_on_deadline: bool,
}

impl SolveControl {
    /// No cancellation, no deadline, no observer — the legacy trait paths.
    pub fn none() -> Self {
        Self::default()
    }

    /// True when the solve should stop at the next phase boundary.
    pub fn should_stop(&self) -> bool {
        if self.cancel_requested() {
            return true;
        }
        if let Some(d) = self.deadline {
            if Instant::now() >= d {
                return true;
            }
        }
        false
    }

    /// True only when the caller's token was cancelled (ignores deadline).
    pub fn cancel_requested(&self) -> bool {
        self.cancel.as_ref().is_some_and(CancelToken::is_cancelled)
    }

    /// Wall-clock budget left before the deadline (None = unbounded).
    /// Saturates at zero once the deadline has passed.
    pub fn remaining(&self) -> Option<Duration> {
        self.deadline.map(|d| d.saturating_duration_since(Instant::now()))
    }

    /// Whether deadline pressure should degrade (coarser ε at a ladder
    /// level boundary) rather than cancel. See the field doc.
    pub fn degrade_on_deadline(&self) -> bool {
        self.degrade_on_deadline
    }

    pub fn report(&self, phase: usize, free: f64) {
        if let Some(obs) = &self.observer {
            obs(Progress { phase, free });
        }
    }
}

impl fmt::Debug for SolveControl {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SolveControl")
            .field("deadline", &self.deadline)
            .field("observer", &self.observer.is_some())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_propagates_to_clones() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t2.is_cancelled());
        t.cancel();
        assert!(t2.is_cancelled());
    }

    #[test]
    fn none_control_never_stops() {
        let ctl = SolveControl::none();
        assert!(!ctl.should_stop());
        ctl.report(1, 0.0); // no observer: must be a no-op, not a panic
    }

    #[test]
    fn report_reaches_observer() {
        use std::sync::atomic::AtomicUsize;
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let ctl = SolveControl {
            cancel: None,
            deadline: None,
            observer: Some(Arc::new(move |p: Progress| {
                assert_eq!(p.phase, 2);
                h.fetch_add(1, Ordering::Relaxed);
            })),
            degrade_on_deadline: false,
        };
        ctl.report(2, 5.0);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn cancel_requested_ignores_deadline() {
        let ctl = SolveControl {
            cancel: Some(CancelToken::new()),
            deadline: Some(Instant::now() - Duration::from_secs(1)),
            observer: None,
            degrade_on_deadline: true,
        };
        assert!(ctl.should_stop(), "expired deadline must trip should_stop");
        assert!(!ctl.cancel_requested(), "token not cancelled");
        assert_eq!(ctl.remaining(), Some(Duration::ZERO));
        ctl.cancel.as_ref().unwrap().cancel();
        assert!(ctl.cancel_requested());
    }
}
