//! Solver-facing control primitives: cancellation, deadlines, progress.
//!
//! These sit in `core` (not `api`) so the algorithm layer can honor
//! cancellation and report progress without depending on the public API
//! layer above it. [`crate::api::SolveRequest`] is the caller-facing
//! builder that snapshots into a [`SolveControl`].

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Note appended to [`crate::solvers::SolveStats::notes`] when a solve was
/// stopped early by cancellation or budget exhaustion.
pub const CANCELLED_NOTE: &str = "cancelled";

/// Shared cancellation flag. Clone freely; all clones observe `cancel()`.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// One progress event, emitted after each completed phase (push-relabel) or
/// stopping-rule check (Sinkhorn).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Progress {
    /// Phase (or iteration) number, 1-based.
    pub phase: usize,
    /// Free mass remaining, in the engine's natural unit: free supply
    /// vertices (assignment), free supply units (OT push-relabel), or the
    /// current marginal violation (Sinkhorn).
    pub free: f64,
}

/// Observer callback; shared so a request can fan out to worker threads.
pub type ProgressFn = Arc<dyn Fn(Progress) + Send + Sync>;

/// Solver-facing cancellation + progress handle. Solvers poll
/// [`SolveControl::should_stop`] between phases and stream
/// (phase, free-mass) events through [`SolveControl::report`].
#[derive(Clone, Default)]
pub struct SolveControl {
    pub(crate) cancel: Option<CancelToken>,
    pub(crate) deadline: Option<Instant>,
    pub(crate) observer: Option<ProgressFn>,
}

impl SolveControl {
    /// No cancellation, no deadline, no observer — the legacy trait paths.
    pub fn none() -> Self {
        Self::default()
    }

    /// True when the solve should stop at the next phase boundary.
    pub fn should_stop(&self) -> bool {
        if let Some(c) = &self.cancel {
            if c.is_cancelled() {
                return true;
            }
        }
        if let Some(d) = self.deadline {
            if Instant::now() >= d {
                return true;
            }
        }
        false
    }

    pub fn report(&self, phase: usize, free: f64) {
        if let Some(obs) = &self.observer {
            obs(Progress { phase, free });
        }
    }
}

impl fmt::Debug for SolveControl {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SolveControl")
            .field("deadline", &self.deadline)
            .field("observer", &self.observer.is_some())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_propagates_to_clones() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t2.is_cancelled());
        t.cancel();
        assert!(t2.is_cancelled());
    }

    #[test]
    fn none_control_never_stops() {
        let ctl = SolveControl::none();
        assert!(!ctl.should_stop());
        ctl.report(1, 0.0); // no observer: must be a no-op, not a panic
    }

    #[test]
    fn report_reaches_observer() {
        use std::sync::atomic::AtomicUsize;
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let ctl = SolveControl {
            cancel: None,
            deadline: None,
            observer: Some(Arc::new(move |p: Progress| {
                assert_eq!(p.phase, 2);
                h.fetch_add(1, Ordering::Relaxed);
            })),
        };
        ctl.report(2, 5.0);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }
}
