//! Crate-wide error type.

use thiserror::Error;

#[derive(Debug, Error)]
pub enum OtprError {
    #[error("invalid instance: {0}")]
    InvalidInstance(String),

    #[error("infeasible: {0}")]
    Infeasible(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for OtprError {
    fn from(e: xla::Error) -> Self {
        OtprError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, OtprError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = OtprError::InvalidInstance("bad mass".into());
        assert_eq!(e.to_string(), "invalid instance: bad mass");
        let e: OtprError = std::io::Error::new(std::io::ErrorKind::NotFound, "x").into();
        assert!(e.to_string().contains("io error"));
    }
}
