//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! offline build carries no `thiserror`).

#[cfg(not(feature = "xla"))]
use crate::runtime::pjrt_stub as xla;
use std::fmt;

#[derive(Debug)]
pub enum OtprError {
    InvalidInstance(String),
    Infeasible(String),
    Artifact(String),
    Runtime(String),
    Coordinator(String),
    Io(std::io::Error),
    Xla(String),
}

impl fmt::Display for OtprError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OtprError::InvalidInstance(m) => write!(f, "invalid instance: {m}"),
            OtprError::Infeasible(m) => write!(f, "infeasible: {m}"),
            OtprError::Artifact(m) => write!(f, "artifact error: {m}"),
            OtprError::Runtime(m) => write!(f, "runtime error: {m}"),
            OtprError::Coordinator(m) => write!(f, "coordinator error: {m}"),
            OtprError::Io(e) => write!(f, "io error: {e}"),
            OtprError::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for OtprError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            OtprError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for OtprError {
    fn from(e: std::io::Error) -> Self {
        OtprError::Io(e)
    }
}

impl From<xla::Error> for OtprError {
    fn from(e: xla::Error) -> Self {
        OtprError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, OtprError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = OtprError::InvalidInstance("bad mass".into());
        assert_eq!(e.to_string(), "invalid instance: bad mass");
        let e: OtprError = std::io::Error::new(std::io::ErrorKind::NotFound, "x").into();
        assert!(e.to_string().contains("io error"));
    }

    #[test]
    fn io_source_preserved() {
        use std::error::Error as _;
        let e: OtprError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.source().is_some());
    }
}
