//! Image workloads (paper §5, Figure 2): normalized 28×28 grayscale images
//! compared under the L1 distance (max cost ≤ 2).
//!
//! The paper uses MNIST. When the real dataset is not on disk (this
//! environment is offline), [`synthetic_digits`] generates MNIST-like
//! images — a random stroke path rendered with Gaussian pens — which match
//! the properties that drive solver behaviour: 28×28, sparse support,
//! unit-normalized mass, L1 costs in [0, 2]. See DESIGN.md §2.

use crate::core::{CostMatrix, L1PointCosts};
use crate::util::pool;
use crate::util::rng::Pcg32;

pub const IMG_SIDE: usize = 28;
pub const IMG_DIM: usize = IMG_SIDE * IMG_SIDE;

/// One image, already normalized so pixel values sum to 1.
pub type Image = Vec<f32>;

/// Normalize pixel values to sum 1 (paper: "images are normalized so that
/// the sum of all pixel values is equal to 1").
pub fn normalize(pixels: &[f32]) -> Image {
    let sum: f32 = pixels.iter().sum();
    if sum <= 0.0 {
        // degenerate blank image: uniform mass
        return vec![1.0 / pixels.len() as f32; pixels.len()];
    }
    pixels.iter().map(|&p| p / sum).collect()
}

/// Generate `n` synthetic digit-like images: 3–6 stroke waypoints joined by
/// line segments, rendered with a Gaussian pen of ~1.2px radius.
pub fn synthetic_digits(n: usize, rng: &mut Pcg32) -> Vec<Image> {
    (0..n).map(|_| synthetic_digit(rng)).collect()
}

fn synthetic_digit(rng: &mut Pcg32) -> Image {
    let mut img = vec![0.0f32; IMG_DIM];
    let waypoints = 3 + rng.next_below(4) as usize;
    // stroke path stays in the central 20x20 region like MNIST digits
    let mut pts: Vec<(f64, f64)> = Vec::with_capacity(waypoints);
    for _ in 0..waypoints {
        pts.push((4.0 + 20.0 * rng.next_f64(), 4.0 + 20.0 * rng.next_f64()));
    }
    let pen_r2 = 1.44; // (1.2 px)^2
    for w in pts.windows(2) {
        let (x0, y0) = w[0];
        let (x1, y1) = w[1];
        let steps = ((x1 - x0).hypot(y1 - y0).ceil() as usize * 2).max(2);
        for s in 0..=steps {
            let t = s as f64 / steps as f64;
            let cx = x0 + t * (x1 - x0);
            let cy = y0 + t * (y1 - y0);
            let lo_i = (cy - 3.0).max(0.0) as usize;
            let hi_i = (cy + 3.0).min(IMG_SIDE as f64 - 1.0) as usize;
            let lo_j = (cx - 3.0).max(0.0) as usize;
            let hi_j = (cx + 3.0).min(IMG_SIDE as f64 - 1.0) as usize;
            for i in lo_i..=hi_i {
                for j in lo_j..=hi_j {
                    let d2 = (i as f64 - cy).powi(2) + (j as f64 - cx).powi(2);
                    let v = (-d2 / pen_r2).exp() as f32;
                    let px = &mut img[i * IMG_SIDE + j];
                    *px = px.max(v);
                }
            }
        }
    }
    normalize(&img)
}

/// L1 distance between two normalized images; bounded by 2.
pub fn l1_distance(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| (x - y).abs()).sum()
}

/// Pairwise L1 cost matrix, rows = B images, cols = A images. The O(n²·784)
/// scan is parallelized over rows.
pub fn l1_costs(b_imgs: &[Image], a_imgs: &[Image]) -> CostMatrix {
    let nb = b_imgs.len();
    let na = a_imgs.len();
    let mut data = vec![0.0f32; nb * na];
    {
        let rows: Vec<&mut [f32]> = data.chunks_mut(na).collect();
        let slots: Vec<std::sync::Mutex<&mut [f32]>> =
            rows.into_iter().map(std::sync::Mutex::new).collect();
        pool::parallel_for_each(nb, pool::default_threads(), |b| {
            // Each row mutex is touched by exactly one closure invocation;
            // recovery keeps the fill total if a sibling row panicked.
            let mut row = slots[b].lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            for a in 0..na {
                row[a] = l1_distance(&b_imgs[b], &a_imgs[a]);
            }
        });
    }
    // panic-ok: L1 distances of normalized images are finite and non-negative
    CostMatrix::from_vec(nb, na, data).expect("l1 costs are valid")
}

/// The implicit (no-slab) form of [`l1_costs`]: an [`L1PointCosts`]
/// provider computing the same L1 distances bit-for-bit from O(n·784)
/// image data instead of the O(n²) matrix.
pub fn l1_cost_provider(b_imgs: &[Image], a_imgs: &[Image]) -> L1PointCosts {
    let costs = L1PointCosts::new(b_imgs.to_vec(), a_imgs.to_vec());
    // panic-ok: generated images share one fixed dimension and finite pixels
    costs.expect("normalized images yield valid costs")
}

/// Images packed as a flat [n, 784] f32 row-major array — the layout the
/// `cost_l1` XLA artifact consumes.
pub fn images_to_f32(imgs: &[Image]) -> Vec<f32> {
    let mut out = Vec::with_capacity(imgs.len() * IMG_DIM);
    for img in imgs {
        debug_assert_eq!(img.len(), IMG_DIM);
        out.extend_from_slice(img);
    }
    out
}

/// The Figure-2 instance at size n (two disjoint synthetic image sets).
pub fn fig2_instance(n: usize, seed: u64) -> CostMatrix {
    let mut rng_a = Pcg32::with_stream(seed, 11);
    let mut rng_b = Pcg32::with_stream(seed, 12);
    let a = synthetic_digits(n, &mut rng_a);
    let b = synthetic_digits(n, &mut rng_b);
    l1_costs(&b, &a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_are_normalized() {
        let mut rng = Pcg32::new(1);
        for img in synthetic_digits(20, &mut rng) {
            let sum: f32 = img.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "sum={sum}");
            assert!(img.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn digits_are_sparse_like_mnist() {
        let mut rng = Pcg32::new(2);
        let img = synthetic_digit(&mut rng);
        let nonzero = img.iter().filter(|&&p| p > 1e-6).count();
        assert!(nonzero > 10, "stroke should cover pixels, got {nonzero}");
        assert!(nonzero < IMG_DIM / 2, "should be sparse, got {nonzero}");
    }

    #[test]
    fn l1_bounds() {
        let mut rng = Pcg32::new(3);
        let imgs = synthetic_digits(10, &mut rng);
        for i in 0..10 {
            assert!(l1_distance(&imgs[i], &imgs[i]) < 1e-6);
            for j in 0..10 {
                let d = l1_distance(&imgs[i], &imgs[j]);
                assert!((0.0..=2.0 + 1e-4).contains(&d));
            }
        }
    }

    #[test]
    fn cost_matrix_matches_scalar_path() {
        let mut rng = Pcg32::new(4);
        let a = synthetic_digits(5, &mut rng);
        let b = synthetic_digits(7, &mut rng);
        let c = l1_costs(&b, &a);
        assert_eq!(c.nb, 7);
        assert_eq!(c.na, 5);
        for i in 0..7 {
            for j in 0..5 {
                assert!((c.at(i, j) - l1_distance(&b[i], &a[j])).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn l1_provider_matches_dense_costs_bit_for_bit() {
        use crate::core::CostProvider;
        let mut rng = Pcg32::new(9);
        let a = synthetic_digits(4, &mut rng);
        let b = synthetic_digits(6, &mut rng);
        let dense = l1_costs(&b, &a);
        let provider = l1_cost_provider(&b, &a);
        assert_eq!(provider.max_cost(), dense.max());
        for i in 0..6 {
            for j in 0..4 {
                assert_eq!(provider.cost_at(i, j), dense.at(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn normalize_blank_is_uniform() {
        let img = normalize(&[0.0; 4]);
        assert_eq!(img, vec![0.25; 4]);
    }

    #[test]
    fn fig2_deterministic() {
        assert_eq!(fig2_instance(6, 9), fig2_instance(6, 9));
    }
}
