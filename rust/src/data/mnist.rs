//! Real-MNIST loading (IDX ubyte format, LeCun 1998).
//!
//! Looks for `train-images-idx3-ubyte` (optionally `.gz`-less only; we read
//! the raw uncompressed file) under `OTPR_MNIST_DIR` or `./data/mnist`. When
//! the files are absent, callers fall back to
//! [`crate::data::images::synthetic_digits`] — the substitution documented
//! in DESIGN.md §2.

use crate::core::error::{OtprError, Result};
use crate::data::images::{normalize, Image, IMG_DIM, IMG_SIDE};
use crate::util::rng::Pcg32;
use std::io::Read;
use std::path::{Path, PathBuf};

const IDX_IMAGES_MAGIC: u32 = 0x0000_0803;

/// Parse an IDX3 ubyte image file into normalized images.
pub fn parse_idx_images(bytes: &[u8]) -> Result<Vec<Image>> {
    if bytes.len() < 16 {
        return Err(OtprError::InvalidInstance("IDX file too short".into()));
    }
    let be32 = |o: usize| u32::from_be_bytes([bytes[o], bytes[o + 1], bytes[o + 2], bytes[o + 3]]);
    if be32(0) != IDX_IMAGES_MAGIC {
        return Err(OtprError::InvalidInstance(format!(
            "bad IDX magic {:#010x}",
            be32(0)
        )));
    }
    let n = be32(4) as usize;
    let rows = be32(8) as usize;
    let cols = be32(12) as usize;
    if rows != IMG_SIDE || cols != IMG_SIDE {
        return Err(OtprError::InvalidInstance(format!(
            "expected 28x28 images, got {rows}x{cols}"
        )));
    }
    let need = 16 + n * IMG_DIM;
    if bytes.len() < need {
        return Err(OtprError::InvalidInstance(format!(
            "IDX truncated: {} < {need}",
            bytes.len()
        )));
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let off = 16 + i * IMG_DIM;
        let raw: Vec<f32> = bytes[off..off + IMG_DIM].iter().map(|&b| b as f32).collect();
        out.push(normalize(&raw));
    }
    Ok(out)
}

fn mnist_dir() -> PathBuf {
    std::env::var("OTPR_MNIST_DIR").map(PathBuf::from).unwrap_or_else(|_| "data/mnist".into())
}

/// Try to load `count` images from the local MNIST copy.
pub fn load_mnist(count: usize) -> Result<Vec<Image>> {
    let path = mnist_dir().join("train-images-idx3-ubyte");
    load_mnist_file(&path, count)
}

pub fn load_mnist_file(path: &Path, count: usize) -> Result<Vec<Image>> {
    let mut file = std::fs::File::open(path)?;
    let mut bytes = Vec::new();
    file.read_to_end(&mut bytes)?;
    let mut imgs = parse_idx_images(&bytes)?;
    if imgs.len() < count {
        return Err(OtprError::InvalidInstance(format!(
            "only {} images available, wanted {count}",
            imgs.len()
        )));
    }
    imgs.truncate(count);
    Ok(imgs)
}

/// Load real MNIST if present, otherwise generate synthetic digit images.
/// Returns (images, used_real_mnist).
pub fn load_or_synthesize(count: usize, seed: u64) -> (Vec<Image>, bool) {
    match load_mnist(count * 2) {
        Ok(mut all) => {
            // split deterministically into two disjoint pools by seed parity
            let mut rng = Pcg32::with_stream(seed, 21);
            rng.shuffle(&mut all);
            all.truncate(count);
            (all, true)
        }
        Err(_) => {
            let mut rng = Pcg32::with_stream(seed, 22);
            (crate::data::images::synthetic_digits(count, &mut rng), false)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny in-memory IDX file with `n` images of constant value v.
    fn fake_idx(n: usize, v: u8) -> Vec<u8> {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&IDX_IMAGES_MAGIC.to_be_bytes());
        bytes.extend_from_slice(&(n as u32).to_be_bytes());
        bytes.extend_from_slice(&28u32.to_be_bytes());
        bytes.extend_from_slice(&28u32.to_be_bytes());
        bytes.extend(std::iter::repeat(v).take(n * IMG_DIM));
        bytes
    }

    #[test]
    fn parses_valid_idx() {
        let imgs = parse_idx_images(&fake_idx(3, 10)).unwrap();
        assert_eq!(imgs.len(), 3);
        let sum: f32 = imgs[0].iter().sum();
        assert!((sum - 1.0).abs() < 1e-4);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = fake_idx(1, 1);
        bytes[3] = 0x01;
        assert!(parse_idx_images(&bytes).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let bytes = fake_idx(2, 1);
        assert!(parse_idx_images(&bytes[..bytes.len() - 5]).is_err());
        assert!(parse_idx_images(&bytes[..10]).is_err());
    }

    #[test]
    fn rejects_wrong_dims() {
        let mut bytes = fake_idx(1, 1);
        bytes[11] = 27;
        assert!(parse_idx_images(&bytes).is_err());
    }

    #[test]
    fn load_file_roundtrip() {
        let dir = std::env::temp_dir().join("otpr_mnist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("train-images-idx3-ubyte");
        std::fs::write(&path, fake_idx(5, 7)).unwrap();
        let imgs = load_mnist_file(&path, 4).unwrap();
        assert_eq!(imgs.len(), 4);
        assert!(load_mnist_file(&path, 6).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn synthesize_fallback() {
        // point the loader at a non-existent dir and expect fallback
        let (imgs, real) = {
            std::env::set_var("OTPR_MNIST_DIR", "/nonexistent/otpr");
            let r = load_or_synthesize(8, 3);
            std::env::remove_var("OTPR_MNIST_DIR");
            r
        };
        assert_eq!(imgs.len(), 8);
        assert!(!real);
    }
}
