//! Workload data: synthetic geometric inputs (Fig 1), image inputs (Fig 2,
//! real MNIST via IDX or synthetic fallback), and named workload descriptors.

pub mod images;
pub mod mnist;
pub mod synthetic;
pub mod workloads;
