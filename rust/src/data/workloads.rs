//! Named workload descriptors shared by benches, examples, and the CLI so
//! every harness builds byte-identical instances for a given (name, seed) —
//! plus the **golden conformance corpus**: tiny fixed instances with exact
//! optima pinned in committed JSON fixtures, swept by every engine in
//! `exp/conformance.rs` and `otpr certify`.

use crate::core::{
    AssignmentInstance, CostMatrix, Costs, GeneratedCosts, OtInstance, OtprError, Result,
};
use crate::data::{images, mnist, synthetic};
use crate::util::minijson::Json;
use crate::util::rng::Pcg32;
use std::path::{Path, PathBuf};

/// A workload that yields an assignment instance.
#[derive(Debug, Clone, PartialEq)]
pub enum Workload {
    /// Figure 1: uniform unit-square points, Euclidean cost.
    Fig1 { n: usize },
    /// Figure 2: (synthetic or real) MNIST-like images, L1 cost.
    Fig2 { n: usize },
    /// Clustered Gaussian-mixture points (ablations).
    Clustered { n: usize, k: usize, sigma: f64 },
    /// Uniform random costs in [0,1] (worst-case-ish, no metric structure).
    RandomCosts { n: usize },
}

impl Workload {
    pub fn name(&self) -> String {
        match self {
            Workload::Fig1 { n } => format!("fig1/n{n}"),
            Workload::Fig2 { n } => format!("fig2/n{n}"),
            Workload::Clustered { n, k, sigma } => format!("clustered/n{n}-k{k}-s{sigma}"),
            Workload::RandomCosts { n } => format!("random/n{n}"),
        }
    }

    pub fn n(&self) -> usize {
        match self {
            Workload::Fig1 { n }
            | Workload::Fig2 { n }
            | Workload::Clustered { n, .. }
            | Workload::RandomCosts { n } => *n,
        }
    }

    /// Build the cost matrix for this workload at `seed`.
    pub fn costs(&self, seed: u64) -> CostMatrix {
        match *self {
            Workload::Fig1 { n } => synthetic::fig1_instance(n, seed),
            Workload::Fig2 { n } => {
                let (a, _) = mnist::load_or_synthesize(n, seed);
                let (b, _) = mnist::load_or_synthesize(n, seed.wrapping_add(0x5EED));
                images::l1_costs(&b, &a)
            }
            Workload::Clustered { n, k, sigma } => {
                let mut ra = Pcg32::with_stream(seed, 31);
                let mut rb = Pcg32::with_stream(seed, 32);
                let a = synthetic::clustered_points(n, k, sigma, &mut ra);
                let b = synthetic::clustered_points(n, k, sigma, &mut rb);
                synthetic::euclidean_costs(&b, &a)
            }
            Workload::RandomCosts { n } => {
                let mut rng = Pcg32::with_stream(seed, 33);
                CostMatrix::from_fn(n, n, |_, _| rng.next_f32())
            }
        }
    }

    pub fn assignment(&self, seed: u64) -> AssignmentInstance {
        // panic-ok: every Workload variant generates a square cost matrix
        AssignmentInstance::new(self.costs(seed)).expect("workloads are square")
    }

    /// The implicit (provider-backed) form of [`Workload::costs`]:
    /// byte-identical costs computed on demand from O(n) data, so solves
    /// never materialize the O(n²) slab. `None` for workloads without a
    /// pure-function form (`RandomCosts` draws a sequential RNG stream).
    pub fn implicit_costs(&self, seed: u64) -> Option<Costs> {
        match *self {
            Workload::Fig1 { n } => {
                let (a, b) = synthetic::fig1_points(n, seed);
                Some(Costs::points(synthetic::euclidean_cost_provider(&b, &a)))
            }
            Workload::Clustered { n, k, sigma } => {
                let mut ra = Pcg32::with_stream(seed, 31);
                let mut rb = Pcg32::with_stream(seed, 32);
                let a = synthetic::clustered_points(n, k, sigma, &mut ra);
                let b = synthetic::clustered_points(n, k, sigma, &mut rb);
                Some(Costs::points(synthetic::euclidean_cost_provider(&b, &a)))
            }
            Workload::Fig2 { n } => {
                let (a, _) = mnist::load_or_synthesize(n, seed);
                let (b, _) = mnist::load_or_synthesize(n, seed.wrapping_add(0x5EED));
                Some(Costs::l1_points(images::l1_cost_provider(&b, &a)))
            }
            Workload::RandomCosts { .. } => None,
        }
    }

    /// OT instance with random (Dirichlet-ish) masses derived from the seed.
    pub fn ot_with_random_masses(&self, seed: u64) -> OtInstance {
        let costs = self.costs(seed);
        let mut rng = Pcg32::with_stream(seed, 34);
        let demand = random_simplex(costs.na, &mut rng);
        let supply = random_simplex(costs.nb, &mut rng);
        // panic-ok: random_simplex emits normalized positive masses
        OtInstance::new(costs, demand, supply).expect("valid masses")
    }

    /// The implicit twin of [`Workload::ot_with_random_masses`]: the same
    /// mass stream over provider-backed costs, so solves are byte-identical
    /// to the dense OT instance while holding O(n) cost bytes. `None` for
    /// workloads without a pure-function cost form.
    pub fn implicit_ot_with_random_masses(
        &self,
        seed: u64,
    ) -> Option<(Costs, Vec<f64>, Vec<f64>)> {
        let costs = self.implicit_costs(seed)?;
        let mut rng = Pcg32::with_stream(seed, 34);
        let demand = random_simplex(costs.na(), &mut rng);
        let supply = random_simplex(costs.nb(), &mut rng);
        Some((costs, demand, supply))
    }
}

// ---------------------------------------------------------------------------
// Golden conformance corpus
// ---------------------------------------------------------------------------

/// Cost formula behind the committed fixtures in `rust/testdata/golden/`
/// (kept in lockstep with `python/tools/gen_golden.py`): every value is a
/// multiple of 1/16, so costs are exact in f32/f64 and the pinned exact
/// optima survive JSON round-trips bit-for-bit.
pub fn golden_cost(b: usize, a: usize, salt: u64) -> f32 {
    (((7 * b as u64 + 11 * a as u64 + 3 * (b as u64) * (a as u64) + salt) % 17) as f32) / 16.0
}

/// Static generator spec of one golden case. Instance construction only —
/// the exact optimum is pinned in the JSON fixture, computed offline in
/// exact rational arithmetic with a duality-certificate optimality proof.
#[derive(Debug, Clone, Copy)]
pub struct GoldenSpec {
    pub name: &'static str,
    pub nb: usize,
    pub na: usize,
    pub salt: u64,
    /// (supply, demand) numerators over 16; `None` = assignment case.
    pub masses16: Option<(&'static [u64], &'static [u64])>,
}

/// The corpus generator, in fixture (alphabetical) order.
pub const GOLDEN_SPECS: &[GoldenSpec] = &[
    GoldenSpec { name: "assign-n4", nb: 4, na: 4, salt: 1, masses16: None },
    GoldenSpec { name: "assign-n5", nb: 5, na: 5, salt: 2, masses16: None },
    GoldenSpec { name: "assign-n6", nb: 6, na: 6, salt: 3, masses16: None },
    GoldenSpec { name: "assign-n8", nb: 8, na: 8, salt: 5, masses16: None },
    GoldenSpec {
        name: "ot-3x4",
        nb: 3,
        na: 4,
        salt: 7,
        masses16: Some((&[8, 5, 3], &[4, 4, 4, 4])),
    },
    GoldenSpec {
        name: "ot-4x4",
        nb: 4,
        na: 4,
        salt: 13,
        masses16: Some((&[4, 4, 4, 4], &[1, 2, 6, 7])),
    },
    GoldenSpec {
        name: "ot-5x5",
        nb: 5,
        na: 5,
        salt: 11,
        masses16: Some((&[6, 4, 3, 2, 1], &[2, 2, 4, 4, 4])),
    },
    GoldenSpec {
        name: "ot-6x6",
        nb: 6,
        na: 6,
        salt: 17,
        masses16: Some((&[2, 2, 2, 2, 4, 4], &[3, 3, 3, 3, 2, 2])),
    },
];

impl GoldenSpec {
    pub fn costs(&self) -> CostMatrix {
        let salt = self.salt;
        CostMatrix::from_fn(self.nb, self.na, |b, a| golden_cost(b, a, salt))
    }

    /// The implicit form of [`GoldenSpec::costs`]: a [`GeneratedCosts`]
    /// closure over the same formula — the dense-vs-implicit golden
    /// equivalence suite runs every engine on both representations.
    pub fn generated(&self) -> Costs {
        let salt = self.salt;
        let gen = GeneratedCosts::new(self.nb, self.na, move |b, a| golden_cost(b, a, salt));
        // panic-ok: golden_cost maps into [0, 1] for all (b, a, salt)
        Costs::generated(gen.expect("golden formula yields valid costs"))
    }

    /// (supply over rows, demand over cols) as probability masses.
    pub fn masses(&self) -> Option<(Vec<f64>, Vec<f64>)> {
        self.masses16.map(|(s, d)| {
            (
                s.iter().map(|&u| u as f64 / 16.0).collect(),
                d.iter().map(|&u| u as f64 / 16.0).collect(),
            )
        })
    }
}

/// One loaded golden case: instance + pinned exact optimum.
#[derive(Debug, Clone)]
pub struct GoldenCase {
    pub name: String,
    pub costs: CostMatrix,
    /// (supply over rows, demand over cols); `None` = assignment case.
    pub masses: Option<(Vec<f64>, Vec<f64>)>,
    /// Exact optimum: Hungarian matching cost for assignment cases, exact
    /// OT cost for transport cases.
    pub exact_cost: f64,
}

impl GoldenCase {
    pub fn is_ot(&self) -> bool {
        self.masses.is_some()
    }

    pub fn n(&self) -> usize {
        self.costs.na.max(self.costs.nb)
    }

    pub fn assignment(&self) -> Option<AssignmentInstance> {
        if self.is_ot() {
            None
        } else {
            AssignmentInstance::new(self.costs.clone()).ok()
        }
    }

    pub fn ot(&self) -> Option<OtInstance> {
        let (supply, demand) = self.masses.clone()?;
        OtInstance::new(self.costs.clone(), demand, supply).ok()
    }
}

/// `rust/testdata/golden`, resolved against the build-time crate root
/// first (always right under `cargo test`/`cargo run`), then against the
/// working directory, so a relocated release binary still finds the
/// fixtures when run from a checkout.
pub fn golden_dir() -> PathBuf {
    let baked = Path::new(env!("CARGO_MANIFEST_DIR")).join("testdata").join("golden");
    if baked.is_dir() {
        return baked;
    }
    for rel in ["rust/testdata/golden", "testdata/golden"] {
        let p = PathBuf::from(rel);
        if p.is_dir() {
            return p;
        }
    }
    baked
}

/// Load the committed corpus (alphabetical by file name, matching
/// [`GOLDEN_SPECS`] order).
pub fn golden_corpus() -> Result<Vec<GoldenCase>> {
    load_golden(&golden_dir())
}

pub fn load_golden(dir: &Path) -> Result<Vec<GoldenCase>> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    paths.sort();
    let mut cases = Vec::new();
    for path in paths {
        let text = std::fs::read_to_string(&path)?;
        let case = Json::parse(&text)
            .and_then(|doc| parse_golden(&doc))
            .map_err(|e| OtprError::InvalidInstance(format!("{}: {e}", path.display())))?;
        cases.push(case);
    }
    if cases.is_empty() {
        return Err(OtprError::InvalidInstance(format!(
            "no golden fixtures found in {} (run python/tools/gen_golden.py)",
            dir.display()
        )));
    }
    Ok(cases)
}

fn parse_golden(doc: &Json) -> std::result::Result<GoldenCase, String> {
    let name = doc.get("name").and_then(Json::as_str).ok_or("missing name")?.to_string();
    let kind = doc.get("kind").and_then(Json::as_str).ok_or("missing kind")?;
    let nb = doc.get("nb").and_then(Json::as_usize).ok_or("missing nb")?;
    let na = doc.get("na").and_then(Json::as_usize).ok_or("missing na")?;
    let exact_cost =
        doc.get("exact_cost").and_then(Json::as_f64).ok_or("missing exact_cost")?;
    let costs = golden_f64_vec(doc, "costs", nb * na)?
        .into_iter()
        .map(|x| x as f32)
        .collect();
    let costs = CostMatrix::from_vec(nb, na, costs).map_err(|e| e.to_string())?;
    let masses = match kind {
        "assignment" => {
            if nb != na {
                return Err(format!("assignment case must be square, got {nb}x{na}"));
            }
            None
        }
        "ot" => Some((golden_f64_vec(doc, "supply", nb)?, golden_f64_vec(doc, "demand", na)?)),
        other => return Err(format!("unknown kind {other:?}")),
    };
    Ok(GoldenCase { name, costs, masses, exact_cost })
}

fn golden_f64_vec(
    doc: &Json,
    key: &str,
    len: usize,
) -> std::result::Result<Vec<f64>, String> {
    let arr = doc.get(key).and_then(Json::as_arr).ok_or_else(|| format!("missing {key}"))?;
    if arr.len() != len {
        return Err(format!("{key} has {} entries, expected {len}", arr.len()));
    }
    arr.iter()
        .map(|v| v.as_f64().ok_or_else(|| format!("non-numeric entry in {key}")))
        .collect()
}

/// Random point on the probability simplex via normalized Exp(1) draws.
pub fn random_simplex(n: usize, rng: &mut Pcg32) -> Vec<f64> {
    let mut v: Vec<f64> = (0..n).map(|_| -(1.0 - rng.next_f64()).ln()).collect();
    let sum: f64 = v.iter().sum();
    for x in &mut v {
        *x /= sum;
    }
    // exact renormalization of the tail element to kill float drift
    let s: f64 = v.iter().take(n - 1).sum();
    v[n - 1] = (1.0 - s).max(0.0);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_sizes() {
        let w = Workload::Fig1 { n: 100 };
        assert_eq!(w.name(), "fig1/n100");
        assert_eq!(w.n(), 100);
    }

    #[test]
    fn deterministic_instances() {
        let w = Workload::RandomCosts { n: 16 };
        assert_eq!(w.costs(1), w.costs(1));
        assert_ne!(w.costs(1), w.costs(2));
    }

    #[test]
    fn simplex_sums_to_one() {
        let mut rng = Pcg32::new(4);
        let v = random_simplex(50, &mut rng);
        assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(v.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn ot_instance_valid() {
        let w = Workload::Fig1 { n: 12 };
        let inst = w.ot_with_random_masses(5);
        assert_eq!(inst.demand.len(), 12);
        assert!((inst.supply.iter().sum::<f64>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn clustered_builds() {
        let w = Workload::Clustered { n: 20, k: 3, sigma: 0.05 };
        let c = w.costs(9);
        assert_eq!(c.na, 20);
    }

    #[test]
    fn implicit_workload_costs_match_dense_bit_for_bit() {
        for w in [
            Workload::Fig1 { n: 13 },
            Workload::Clustered { n: 10, k: 3, sigma: 0.05 },
            Workload::Fig2 { n: 4 },
        ] {
            let dense = w.costs(7);
            let implicit = w.implicit_costs(7).expect("workload has an implicit form");
            assert_eq!((implicit.nb(), implicit.na()), (dense.nb, dense.na), "{}", w.name());
            assert_eq!(implicit.max_cost(), dense.max(), "{}", w.name());
            for b in 0..dense.nb {
                for a in 0..dense.na {
                    assert_eq!(implicit.at(b, a), dense.at(b, a), "{} ({b},{a})", w.name());
                }
            }
        }
        assert!(Workload::RandomCosts { n: 8 }.implicit_costs(1).is_none());
        // the golden generator has an implicit form too
        let spec = &GOLDEN_SPECS[0];
        let implicit = spec.generated();
        let dense = spec.costs();
        for b in 0..spec.nb {
            for a in 0..spec.na {
                assert_eq!(implicit.at(b, a), dense.at(b, a), "{} ({b},{a})", spec.name);
            }
        }
    }

    #[test]
    fn golden_specs_are_well_formed() {
        for spec in GOLDEN_SPECS {
            let costs = spec.costs();
            assert_eq!((costs.nb, costs.na), (spec.nb, spec.na), "{}", spec.name);
            assert!(costs.max() <= 1.0, "{}: costs above 1", spec.name);
            if let Some((supply, demand)) = spec.masses() {
                assert_eq!(supply.len(), spec.nb, "{}", spec.name);
                assert_eq!(demand.len(), spec.na, "{}", spec.name);
                assert!((supply.iter().sum::<f64>() - 1.0).abs() < 1e-12);
                assert!((demand.iter().sum::<f64>() - 1.0).abs() < 1e-12);
            } else {
                assert_eq!(spec.nb, spec.na, "{}: assignment must be square", spec.name);
            }
        }
    }

    #[test]
    fn golden_fixtures_match_generator() {
        let corpus = golden_corpus().expect("committed fixtures load");
        assert_eq!(corpus.len(), GOLDEN_SPECS.len(), "fixture/spec count drift");
        for (case, spec) in corpus.iter().zip(GOLDEN_SPECS) {
            assert_eq!(case.name, spec.name, "fixture order drift");
            assert_eq!(case.costs, spec.costs(), "{}: costs drifted from formula", spec.name);
            assert_eq!(case.masses, spec.masses(), "{}: masses drifted", spec.name);
            assert!(case.exact_cost.is_finite() && case.exact_cost >= 0.0);
            assert_eq!(case.is_ot(), spec.masses16.is_some());
            if case.is_ot() {
                assert!(case.ot().is_some() && case.assignment().is_none());
            } else {
                assert!(case.assignment().is_some() && case.ot().is_none());
            }
        }
    }
}
