//! Named workload descriptors shared by benches, examples, and the CLI so
//! every harness builds byte-identical instances for a given (name, seed).

use crate::core::{AssignmentInstance, CostMatrix, OtInstance};
use crate::data::{images, mnist, synthetic};
use crate::util::rng::Pcg32;

/// A workload that yields an assignment instance.
#[derive(Debug, Clone, PartialEq)]
pub enum Workload {
    /// Figure 1: uniform unit-square points, Euclidean cost.
    Fig1 { n: usize },
    /// Figure 2: (synthetic or real) MNIST-like images, L1 cost.
    Fig2 { n: usize },
    /// Clustered Gaussian-mixture points (ablations).
    Clustered { n: usize, k: usize, sigma: f64 },
    /// Uniform random costs in [0,1] (worst-case-ish, no metric structure).
    RandomCosts { n: usize },
}

impl Workload {
    pub fn name(&self) -> String {
        match self {
            Workload::Fig1 { n } => format!("fig1/n{n}"),
            Workload::Fig2 { n } => format!("fig2/n{n}"),
            Workload::Clustered { n, k, sigma } => format!("clustered/n{n}-k{k}-s{sigma}"),
            Workload::RandomCosts { n } => format!("random/n{n}"),
        }
    }

    pub fn n(&self) -> usize {
        match self {
            Workload::Fig1 { n }
            | Workload::Fig2 { n }
            | Workload::Clustered { n, .. }
            | Workload::RandomCosts { n } => *n,
        }
    }

    /// Build the cost matrix for this workload at `seed`.
    pub fn costs(&self, seed: u64) -> CostMatrix {
        match *self {
            Workload::Fig1 { n } => synthetic::fig1_instance(n, seed),
            Workload::Fig2 { n } => {
                let (a, _) = mnist::load_or_synthesize(n, seed);
                let (b, _) = mnist::load_or_synthesize(n, seed.wrapping_add(0x5EED));
                images::l1_costs(&b, &a)
            }
            Workload::Clustered { n, k, sigma } => {
                let mut ra = Pcg32::with_stream(seed, 31);
                let mut rb = Pcg32::with_stream(seed, 32);
                let a = synthetic::clustered_points(n, k, sigma, &mut ra);
                let b = synthetic::clustered_points(n, k, sigma, &mut rb);
                synthetic::euclidean_costs(&b, &a)
            }
            Workload::RandomCosts { n } => {
                let mut rng = Pcg32::with_stream(seed, 33);
                CostMatrix::from_fn(n, n, |_, _| rng.next_f32())
            }
        }
    }

    pub fn assignment(&self, seed: u64) -> AssignmentInstance {
        AssignmentInstance::new(self.costs(seed)).expect("workloads are square")
    }

    /// OT instance with random (Dirichlet-ish) masses derived from the seed.
    pub fn ot_with_random_masses(&self, seed: u64) -> OtInstance {
        let costs = self.costs(seed);
        let mut rng = Pcg32::with_stream(seed, 34);
        let demand = random_simplex(costs.na, &mut rng);
        let supply = random_simplex(costs.nb, &mut rng);
        OtInstance::new(costs, demand, supply).expect("valid masses")
    }
}

/// Random point on the probability simplex via normalized Exp(1) draws.
pub fn random_simplex(n: usize, rng: &mut Pcg32) -> Vec<f64> {
    let mut v: Vec<f64> = (0..n).map(|_| -(1.0 - rng.next_f64()).ln()).collect();
    let sum: f64 = v.iter().sum();
    for x in &mut v {
        *x /= sum;
    }
    // exact renormalization of the tail element to kill float drift
    let s: f64 = v.iter().take(n - 1).sum();
    v[n - 1] = (1.0 - s).max(0.0);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_sizes() {
        let w = Workload::Fig1 { n: 100 };
        assert_eq!(w.name(), "fig1/n100");
        assert_eq!(w.n(), 100);
    }

    #[test]
    fn deterministic_instances() {
        let w = Workload::RandomCosts { n: 16 };
        assert_eq!(w.costs(1), w.costs(1));
        assert_ne!(w.costs(1), w.costs(2));
    }

    #[test]
    fn simplex_sums_to_one() {
        let mut rng = Pcg32::new(4);
        let v = random_simplex(50, &mut rng);
        assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(v.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn ot_instance_valid() {
        let w = Workload::Fig1 { n: 12 };
        let inst = w.ot_with_random_masses(5);
        assert_eq!(inst.demand.len(), 12);
        assert!((inst.supply.iter().sum::<f64>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn clustered_builds() {
        let w = Workload::Clustered { n: 20, k: 3, sigma: 0.05 };
        let c = w.costs(9);
        assert_eq!(c.na, 20);
    }
}
