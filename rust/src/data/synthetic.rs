//! Synthetic geometric workloads (paper §5, Figure 1): point sets A and B
//! sampled uniformly from the unit square, costs = Euclidean distances.

use crate::core::{CostMatrix, SqEuclideanCosts};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point2 {
    pub x: f64,
    pub y: f64,
}

impl Point2 {
    pub fn dist(&self, other: &Point2) -> f64 {
        let dx = self.x - other.x;
        let dy = self.y - other.y;
        (dx * dx + dy * dy).sqrt()
    }
}

/// Sample `n` points uniformly from [0,1]².
pub fn uniform_points(n: usize, rng: &mut Pcg32) -> Vec<Point2> {
    (0..n).map(|_| Point2 { x: rng.next_f64(), y: rng.next_f64() }).collect()
}

/// Euclidean cost matrix: rows = B, columns = A.
pub fn euclidean_costs(b_pts: &[Point2], a_pts: &[Point2]) -> CostMatrix {
    CostMatrix::from_fn(b_pts.len(), a_pts.len(), |b, a| b_pts[b].dist(&a_pts[a]) as f32)
}

/// The Figure-1 point sets: A, B ~ U([0,1]²)ⁿ — `(a_pts, b_pts)`.
pub fn fig1_points(n: usize, seed: u64) -> (Vec<Point2>, Vec<Point2>) {
    let mut rng_a = Pcg32::with_stream(seed, 1);
    let mut rng_b = Pcg32::with_stream(seed, 2);
    let a = uniform_points(n, &mut rng_a);
    let b = uniform_points(n, &mut rng_b);
    (a, b)
}

/// The Figure-1 instance: A, B ~ U([0,1]²)ⁿ, Euclidean costs (max ≤ √2).
pub fn fig1_instance(n: usize, seed: u64) -> CostMatrix {
    let (a, b) = fig1_points(n, seed);
    euclidean_costs(&b, &a)
}

/// The implicit (no-slab) form of [`euclidean_costs`]: a
/// [`SqEuclideanCosts`] provider computing the same Euclidean distances
/// bit-for-bit from O(n) point data.
pub fn euclidean_cost_provider(b_pts: &[Point2], a_pts: &[Point2]) -> SqEuclideanCosts {
    let to_core = |pts: &[Point2]| pts.iter().map(|p| [p.x, p.y]).collect::<Vec<[f64; 2]>>();
    let costs = SqEuclideanCosts::euclidean(to_core(b_pts), to_core(a_pts));
    // panic-ok: sampled points are finite by construction (unit square)
    costs.expect("finite unit-square points yield valid costs")
}

/// Points packed as a flat [n,2] f32 row-major array — the layout the
/// `cost_euclid` XLA artifact consumes.
pub fn points_to_f32(pts: &[Point2]) -> Vec<f32> {
    let mut out = Vec::with_capacity(pts.len() * 2);
    for p in pts {
        out.push(p.x as f32);
        out.push(p.y as f32);
    }
    out
}

/// Clustered (Gaussian-mixture) points: a harder geometric workload used by
/// the ablation benches; `k` centers, isotropic stddev `sigma`, clipped to
/// the unit square.
pub fn clustered_points(n: usize, k: usize, sigma: f64, rng: &mut Pcg32) -> Vec<Point2> {
    let centers: Vec<Point2> = (0..k.max(1))
        .map(|_| Point2 { x: rng.next_f64(), y: rng.next_f64() })
        .collect();
    (0..n)
        .map(|_| {
            let c = centers[rng.next_below(centers.len() as u32) as usize];
            Point2 {
                x: (c.x + sigma * rng.normal()).clamp(0.0, 1.0),
                y: (c.y + sigma * rng.normal()).clamp(0.0, 1.0),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn points_in_unit_square() {
        let mut rng = Pcg32::new(1);
        for p in uniform_points(500, &mut rng) {
            assert!((0.0..1.0).contains(&p.x) && (0.0..1.0).contains(&p.y));
        }
    }

    #[test]
    fn costs_are_metric_distances() {
        let mut rng = Pcg32::new(2);
        let a = uniform_points(10, &mut rng);
        let b = uniform_points(10, &mut rng);
        let c = euclidean_costs(&b, &a);
        assert!(c.max() <= (2.0f32).sqrt() + 1e-6);
        for i in 0..10 {
            for j in 0..10 {
                assert!((c.at(i, j) as f64 - b[i].dist(&a[j])).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn fig1_deterministic_per_seed() {
        let c1 = fig1_instance(50, 7);
        let c2 = fig1_instance(50, 7);
        let c3 = fig1_instance(50, 8);
        assert_eq!(c1, c2);
        assert_ne!(c1, c3);
        // A and B streams differ: diagonal should not be all ~0
        let diag_sum: f32 = (0..50).map(|i| c1.at(i, i)).sum();
        assert!(diag_sum > 1.0);
    }

    #[test]
    fn packed_points_layout() {
        let pts = vec![Point2 { x: 0.25, y: 0.5 }, Point2 { x: 1.0, y: 0.0 }];
        assert_eq!(points_to_f32(&pts), vec![0.25, 0.5, 1.0, 0.0]);
    }

    #[test]
    fn provider_matches_dense_costs_bit_for_bit() {
        use crate::core::CostProvider;
        let (a, b) = fig1_points(23, 11); // non-multiple-of-8 width
        let dense = euclidean_costs(&b, &a);
        let provider = euclidean_cost_provider(&b, &a);
        assert_eq!(provider.max_cost(), dense.max(), "identical normalization constant");
        for i in 0..23 {
            for j in 0..23 {
                assert_eq!(provider.cost_at(i, j), dense.at(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn clustered_points_clipped() {
        let mut rng = Pcg32::new(3);
        let pts = clustered_points(300, 4, 0.3, &mut rng);
        assert_eq!(pts.len(), 300);
        for p in pts {
            assert!((0.0..=1.0).contains(&p.x) && (0.0..=1.0).contains(&p.y));
        }
    }
}
