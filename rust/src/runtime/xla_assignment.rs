//! Device-resident push-relabel assignment solve over the AOT artifacts —
//! the "GPU implementation" analog of the paper on this testbed.
//!
//! The phase loop keeps the O(n²) quantized cost matrix on the PJRT device
//! permanently; per phase it chains the packed state buffer through
//! `phase_step_{n}` and reads back **8 bytes** (the free-count / rounds
//! meta) to decide termination. Costs themselves can be built on-device
//! from points/images (`solve_points` / `solve_images`), so the host never
//! touches an n² object on those paths. All device work runs on the
//! [`crate::runtime::client::XlaService`] thread.

use crate::core::matching::{Matching, FREE};
use crate::core::{AssignmentInstance, CostMatrix, OtprError, Result};
use crate::runtime::client::{download_i32, run1, XlaContext, XlaRuntime};
#[cfg(not(feature = "xla"))]
use crate::runtime::pjrt_stub as xla;
use crate::solvers::{AssignmentSolution, AssignmentSolver, SolveStats};
use crate::util::timer::Stopwatch;
use std::sync::Arc;

/// Pad an assignment cost matrix to `size`: cross edges (real↔pad) cost
/// `c_max`, pad↔pad edges cost 0. An exchange argument shows padded optima
/// keep real vertices together; approximate crossings are repaired after
/// the solve at no extra error (the crossing already paid ≥ c_max).
pub fn pad_assignment_costs(costs: &CostMatrix, size: usize) -> CostMatrix {
    assert!(costs.na == costs.nb && size >= costs.na);
    let n = costs.na;
    let c_max = costs.max();
    CostMatrix::from_fn(size, size, |b, a| match (b < n, a < n) {
        (true, true) => costs.at(b, a),
        (false, false) => 0.0,
        _ => c_max,
    })
}

/// Raw outcome of the device phase loop (Send-able back to callers).
struct LoopOutcome {
    match_b: Vec<i32>,
    phases: usize,
    rounds: usize,
}

/// Phases the `multi_phase_{n}` artifact executes per host round trip.
/// §Perf (EXPERIMENTS.md): the per-call dispatch + O(n) state download
/// dominates small-n solves; batching K phases on-device amortizes it.
/// Overshoot past the threshold is bounded by K−1 extra phases, which only
/// *reduces* the number of arbitrarily-completed vertices.
pub const PHASES_PER_CALL: i32 = 16;

/// Drive the device phase loop until `free ≤ threshold` (runs on the
/// service thread; `cq_buf` must be an i32[n,n] device buffer). Prefers
/// the batched `multi_phase` artifact; falls back to per-phase
/// `phase_step` for manifests that predate it.
fn phase_loop(
    ctx: &mut XlaContext,
    cq_buf: &xla::PjRtBuffer,
    n: usize,
    threshold: usize,
    eps_eff: f64,
) -> Result<LoopOutcome> {
    let multi_exe = ctx.executable("multi_phase", n).ok();
    let phase_exe =
        if multi_exe.is_none() { Some(ctx.executable("phase_step", n)?) } else { None };
    // packed init state: ya=0, yb=1, ma=mb=-1, meta=0
    let mut state = vec![0i32; 5 * n];
    state[n..2 * n].fill(1);
    state[2 * n..4 * n].fill(-1);
    let mut state_buf = ctx.upload_i32(&state, &[5, n])?;
    let params_buf = ctx.upload_i32(&[threshold as i32, PHASES_PER_CALL], &[2])?;
    let cap = crate::solvers::push_relabel::assignment_phase_cap(eps_eff);
    let mut phases = 0usize;
    let mut rounds = 0usize;
    loop {
        // meta row layout: [free_count, rounds, phases(multi only), 0, ...]
        // at offset 4n of the packed state. CopyRawToHost is unimplemented
        // on this PJRT build, so the whole O(n) state literal is pulled —
        // still tiny next to the device-resident O(n²) cost matrix.
        let executed;
        match (&multi_exe, &phase_exe) {
            (Some(exe), _) => {
                state_buf = run1(exe, &[cq_buf, &state_buf, &params_buf])?;
                let head = download_i32(&state_buf, 5 * n)?;
                executed = head[4 * n + 2] as usize;
                phases += executed;
                rounds += head[4 * n + 1] as usize;
                let free = head[4 * n];
                if (free as usize) <= threshold || executed == 0 {
                    return Ok(LoopOutcome {
                        match_b: head[3 * n..4 * n].to_vec(),
                        phases,
                        rounds,
                    });
                }
            }
            (_, Some(exe)) => {
                state_buf = run1(exe, &[cq_buf, &state_buf])?;
                let head = download_i32(&state_buf, 5 * n)?;
                phases += 1;
                rounds += head[4 * n + 1] as usize;
                let free = head[4 * n];
                if (free as usize) <= threshold {
                    return Ok(LoopOutcome {
                        match_b: head[3 * n..4 * n].to_vec(),
                        phases,
                        rounds,
                    });
                }
            }
            // panic-ok: the loader guarantees one of the two artifact forms
            _ => unreachable!("artifact bundle lost both phase executables"),
        }
        if phases > cap {
            return Err(OtprError::Runtime(format!(
                "XLA phase cap {cap} exceeded at {phases} phases"
            )));
        }
    }
}

/// Assignment engine over XLA artifacts.
pub struct XlaAssignment {
    pub runtime: Arc<XlaRuntime>,
}

impl XlaAssignment {
    pub fn new(runtime: Arc<XlaRuntime>) -> Self {
        Self { runtime }
    }

    /// Shared tail: trim a bucket-sized match vector to the real instance,
    /// repair pad crossings, complete, and cost it.
    fn finalize(
        &self,
        inst: &AssignmentInstance,
        out: LoopOutcome,
        bucket: usize,
        sw: Stopwatch,
    ) -> Result<AssignmentSolution> {
        let n = inst.n();
        let mut m = Matching::empty(n, n);
        for b in 0..n {
            let a = out.match_b[b];
            if a != FREE && (a as usize) < n && m.is_a_free(a as usize) {
                m.link(b, a as usize);
            }
            // b matched to a pad column (or conflict): repaired below
        }
        m.complete_arbitrarily();
        debug_assert!(m.is_perfect());
        let cost = m.cost(&inst.costs);
        Ok(AssignmentSolution {
            matching: m,
            cost,
            // duals stay device-side; only the match vector is downloaded
            duals: None,
            stats: SolveStats {
                phases: out.phases,
                total_free_processed: 0,
                rounds: out.rounds,
                seconds: sw.elapsed_secs(),
                notes: vec![format!("bucket={bucket}")],
                ..Default::default()
            },
        })
    }

    /// Solve from an explicit cost matrix (any n ≤ max bucket): pads on the
    /// host, quantizes on device, then runs the device loop.
    pub fn solve_costs(
        &self,
        inst: &AssignmentInstance,
        eps_param: f64,
    ) -> Result<AssignmentSolution> {
        let sw = Stopwatch::start();
        let n = inst.n();
        let bucket = self.runtime.registry.bucket_for(n)?;
        // keep the additive budget ε·n·c_max after padding to `bucket`
        let eps_eff = (eps_param * n as f64 / bucket as f64).max(1e-6);
        let padded = pad_assignment_costs(&inst.costs, bucket);
        let c_max = padded.max() as f64;
        let inv = if c_max > 0.0 { 1.0 / (eps_eff * c_max) } else { 1.0 };
        let threshold = (eps_eff * bucket as f64).floor() as usize;
        let padded_data: Vec<f32> = padded.as_slice().to_vec();

        let out = self.runtime.call(move |ctx| {
            let costs_buf = ctx.upload_f32(&padded_data, &[bucket, bucket])?;
            let inv_buf = ctx.upload_f32(&[inv as f32], &[1])?;
            let quant_exe = ctx.executable("quantize", bucket)?;
            let cq_buf = run1(&quant_exe, &[&costs_buf, &inv_buf])?;
            phase_loop(ctx, &cq_buf, bucket, threshold, eps_eff)
        })?;
        self.finalize(inst, out, bucket, sw)
    }

    /// Fig-1 fast path: upload [n,2] points, build + quantize the cost
    /// matrix on device. Requires n to be an exact artifact size (falls
    /// back to `solve_costs` otherwise).
    pub fn solve_points(
        &self,
        pts_b: &[f32],
        pts_a: &[f32],
        inst: &AssignmentInstance,
        eps_param: f64,
    ) -> Result<AssignmentSolution> {
        self.solve_built(inst, eps_param, "cost_euclid", pts_b, pts_a, 2)
    }

    /// Fig-2 fast path: upload [n,784] images.
    pub fn solve_images(
        &self,
        imgs_b: &[f32],
        imgs_a: &[f32],
        inst: &AssignmentInstance,
        eps_param: f64,
    ) -> Result<AssignmentSolution> {
        self.solve_built(inst, eps_param, "cost_l1", imgs_b, imgs_a, 784)
    }

    fn solve_built(
        &self,
        inst: &AssignmentInstance,
        eps_param: f64,
        cost_kind: &'static str,
        feat_b: &[f32],
        feat_a: &[f32],
        dim: usize,
    ) -> Result<AssignmentSolution> {
        let sw = Stopwatch::start();
        let n = inst.n();
        if !self.runtime.registry.sizes.contains(&n) {
            // fall back to the padded cost path
            return self.solve_costs(inst, eps_param);
        }
        assert_eq!(feat_b.len(), n * dim);
        assert_eq!(feat_a.len(), n * dim);
        let threshold = (eps_param * n as f64).floor() as usize;
        let fb: Vec<f32> = feat_b.to_vec();
        let fa: Vec<f32> = feat_a.to_vec();
        let out = self.runtime.call(move |ctx| {
            let fb = ctx.upload_f32(&fb, &[n, dim])?;
            let fa = ctx.upload_f32(&fa, &[n, dim])?;
            let cost_exe = ctx.executable(cost_kind, n)?;
            let costs_buf = run1(&cost_exe, &[&fb, &fa])?;
            let max_exe = ctx.executable("matrix_max", n)?;
            let cmax_buf = run1(&max_exe, &[&costs_buf])?;
            let c_max = crate::runtime::client::download_f32(&cmax_buf, 1)?[0] as f64;
            let inv = if c_max > 0.0 { 1.0 / (eps_param * c_max) } else { 1.0 };
            let inv_buf = ctx.upload_f32(&[inv as f32], &[1])?;
            let quant_exe = ctx.executable("quantize", n)?;
            let cq_buf = run1(&quant_exe, &[&costs_buf, &inv_buf])?;
            phase_loop(ctx, &cq_buf, n, threshold, eps_param)
        })?;
        self.finalize(inst, out, n, sw)
    }
}

impl AssignmentSolver for XlaAssignment {
    fn name(&self) -> &'static str {
        "push-relabel-xla"
    }

    fn solve_assignment(&self, inst: &AssignmentInstance, eps: f64) -> Result<AssignmentSolution> {
        self.solve_costs(inst, eps / 3.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_scheme() {
        let c = CostMatrix::from_fn(2, 2, |b, a| 0.1 + (b + a) as f32 * 0.2);
        let p = pad_assignment_costs(&c, 4);
        assert_eq!(p.at(1, 1), c.at(1, 1));
        assert_eq!(p.at(3, 3), 0.0);
        assert_eq!(p.at(0, 3), c.max());
        assert_eq!(p.at(3, 0), c.max());
    }

    // End-to-end runtime tests live in rust/tests/integration_runtime.rs
    // (they need `make artifacts` to have run).
}
