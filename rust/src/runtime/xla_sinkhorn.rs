//! Device-resident Sinkhorn baseline over the `sinkhorn_step_{n}` artifact
//! (the paper's "Sinkhorn-GPU" comparator on this testbed).
//!
//! Costs upload once; the packed (u, v, err) state chains through
//! `execute_b` with a 4-byte host read per sweep for the stopping rule.
//! Parameterization matches `solvers::sinkhorn` (η = ε·c_max/(4·ln n),
//! stop at marginal violation ε/8) so native-vs-XLA comparisons are
//! apples-to-apples.

use crate::core::{OtInstance, OtprError, Result, TransportPlan};
use crate::runtime::client::{download_f32, run1, XlaRuntime};
use crate::solvers::sinkhorn::round_to_feasible;
use crate::solvers::{OtSolution, OtSolver, SolveStats};
use crate::util::timer::Stopwatch;
use std::sync::Arc;

pub struct XlaSinkhorn {
    pub runtime: Arc<XlaRuntime>,
    pub max_iters: usize,
}

impl XlaSinkhorn {
    pub fn new(runtime: Arc<XlaRuntime>) -> Self {
        Self { runtime, max_iters: 100_000 }
    }
}

impl OtSolver for XlaSinkhorn {
    fn name(&self) -> &'static str {
        "sinkhorn-xla"
    }

    fn solve_ot(&self, inst: &OtInstance, eps: f64) -> Result<OtSolution> {
        let sw = Stopwatch::start();
        let n = inst.costs.na;
        if inst.costs.nb != n {
            return Err(OtprError::InvalidInstance(
                "xla sinkhorn requires square instances".into(),
            ));
        }
        let bucket = self.runtime.registry.bucket_for(n)?;
        // pad with zero-mass rows/cols and zero costs — inert under the
        // scaling updates (u_pad = 0/Kv = 0) and invisible to the marginal
        // error.
        let padded = inst.costs.padded(bucket, bucket, 0.0);
        let mut r = vec![0f32; bucket];
        let mut c = vec![0f32; bucket];
        for (i, &m) in inst.supply.iter().enumerate() {
            r[i] = m as f32;
        }
        for (i, &m) in inst.demand.iter().enumerate() {
            c[i] = m as f32;
        }
        let c_max = (inst.costs.max() as f64).max(1e-30);
        let eta = (eps * c_max / (4.0 * (n.max(2) as f64).ln())).max(1e-12) as f32;
        let tol = (eps / 8.0) as f32;
        let max_iters = self.max_iters;
        let padded_data: Vec<f32> = padded.as_slice().to_vec();

        let (u, v, iters, notes) = self.runtime.call(move |ctx| {
            let costs_buf = ctx.upload_f32(&padded_data, &[bucket, bucket])?;
            let r_buf = ctx.upload_f32(&r, &[bucket])?;
            let c_buf = ctx.upload_f32(&c, &[bucket])?;
            let eta_buf = ctx.upload_f32(&[eta], &[1])?;
            let exe = ctx.executable("sinkhorn_step", bucket)?;
            // packed state rows: u=1, v=1, meta=0
            let mut state = vec![1f32; 2 * bucket];
            state.extend(std::iter::repeat(0f32).take(bucket));
            let mut state_buf = ctx.upload_f32(&state, &[3, bucket])?;
            let mut iters = 0usize;
            let mut notes = Vec::new();
            loop {
                state_buf = run1(&exe, &[&costs_buf, &state_buf, &r_buf, &c_buf, &eta_buf])?;
                iters += 1;
                let state_host = download_f32(&state_buf, 3 * bucket)?;
                let err = state_host[2 * bucket];
                if !err.is_finite() {
                    return Err(OtprError::Infeasible(format!(
                        "xla sinkhorn diverged (underflow) at iter {iters}, eta={eta:.3e}"
                    )));
                }
                if err < tol || iters >= max_iters {
                    if iters >= max_iters {
                        notes.push(format!("hit max_iters={max_iters} err={err}"));
                    }
                    break;
                }
            }
            let full = download_f32(&state_buf, 3 * bucket)?;
            Ok((full[..bucket].to_vec(), full[bucket..2 * bucket].to_vec(), iters, notes))
        })?;

        // Plan assembly + Altschuler rounding on the host (one O(n²) pass).
        let mut plan = TransportPlan::zeros(n, n);
        let eta = eta as f64;
        for b in 0..n {
            for a in 0..n {
                let k = (-(inst.costs.at(b, a) as f64) / eta).exp();
                plan.set(b, a, u[b] as f64 * k * v[a] as f64);
            }
        }
        let plan = round_to_feasible(&plan, &inst.supply, &inst.demand);
        let cost = plan.cost(&inst.costs);
        Ok(OtSolution {
            plan,
            cost,
            duals: None,
            stats: SolveStats {
                phases: iters,
                seconds: sw.elapsed_secs(),
                notes,
                ..Default::default()
            },
        })
    }
}
