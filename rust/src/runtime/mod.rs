//! PJRT runtime: loads the HLO-text artifacts that `python/compile/aot.py`
//! emitted (JAX model + Pallas kernels, AOT) and drives them with
//! device-resident buffers on a dedicated service thread. This is the
//! L3↔L2 boundary: Python never runs at request time.

pub mod artifact;
pub mod client;
pub mod xla_assignment;
pub mod xla_sinkhorn;

pub use artifact::{ArtifactRegistry, ArtifactSpec};
pub use client::{XlaRuntime, XlaService};
pub use xla_assignment::XlaAssignment;
pub use xla_sinkhorn::XlaSinkhorn;
