//! PJRT runtime: loads the HLO-text artifacts that `python/compile/aot.py`
//! emitted (JAX model + Pallas kernels, AOT) and drives them with
//! device-resident buffers on a dedicated service thread. This is the
//! L3↔L2 boundary: Python never runs at request time.

// The `xla` cargo feature swaps the in-tree PJRT stub for the real `xla`
// bindings crate, which must be added to rust/Cargo.toml [dependencies]
// from the offline registry (it is not declared as an optional dependency
// on purpose — resolution would then require the registry even for
// default builds). Fail loudly with instructions instead of a wall of
// unresolved `xla::` imports; delete this guard when adding the crate.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature requires the real PJRT bindings: add the `xla` crate \
     (xla_extension 0.5.1 closure, offline registry) to rust/Cargo.toml \
     [dependencies] and remove this compile_error in rust/src/runtime/mod.rs"
);

pub mod artifact;
pub mod client;
pub mod pjrt_stub;
pub mod xla_assignment;
pub mod xla_sinkhorn;

pub use artifact::{ArtifactRegistry, ArtifactSpec};
pub use client::{XlaRuntime, XlaService};
pub use xla_assignment::XlaAssignment;
pub use xla_sinkhorn::XlaSinkhorn;
