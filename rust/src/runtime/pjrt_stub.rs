//! In-tree stand-in for the `xla` bindings crate (xla_extension 0.5.1).
//!
//! The offline registry that ships the real PJRT closure is not always
//! available, so the runtime layer is compiled against this module unless
//! the `xla` cargo feature is enabled (see `Cargo.toml`). The stub keeps
//! the exact API surface [`crate::runtime::client`] uses:
//!
//! * host buffers round-trip (`buffer_from_host_buffer` →
//!   `to_literal_sync`/`to_vec`), so the service-thread plumbing and its
//!   tests run unchanged;
//! * compilation and execution report a clean "built without the `xla`
//!   feature" error, which the router surfaces as "artifacts unavailable"
//!   and callers fall back to the native engines.
//!
//! Swapping in the real crate is a Cargo.toml change only — no call sites
//! move, because every `xla::` path in the runtime resolves through a
//! `#[cfg]` alias to either this module or the external crate.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` conversion.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!("{what} unavailable: built without the `xla` feature (PJRT stub active)"))
}

/// Element types the runtime moves across the host/device boundary.
#[derive(Debug, Clone)]
pub enum HostData {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

/// Sealed-ish helper so upload/download stay generic like the real crate.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> HostData;
    fn unwrap(data: &HostData) -> Option<Vec<Self>>;
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> HostData {
        HostData::I32(data)
    }
    fn unwrap(data: &HostData) -> Option<Vec<Self>> {
        match data {
            HostData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> HostData {
        HostData::F32(data)
    }
    fn unwrap(data: &HostData) -> Option<Vec<Self>> {
        match data {
            HostData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-memory "device" buffer.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    data: HostData,
    pub dims: Vec<usize>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Ok(Literal { data: self.data.clone() })
    }
}

/// Host literal downloaded from a buffer.
#[derive(Debug, Clone)]
pub struct Literal {
    data: HostData,
}

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal element type mismatch".into()))
    }
}

/// Parsed HLO module placeholder; parsing requires the real crate.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(unavailable("HLO parsing"))
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Compiled executable placeholder; never constructed by the stub client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("executable execution"))
    }
}

/// Stub client: buffers round-trip in host memory, compilation errors out.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("XLA compilation"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        let expect: usize = dims.iter().product();
        if expect != data.len() {
            return Err(Error(format!(
                "host buffer length {} does not match dims {:?}",
                data.len(),
                dims
            )));
        }
        Ok(PjRtBuffer { data: T::wrap(data.to_vec()), dims: dims.to_vec() })
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn device_count(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_roundtrip_typed() {
        let client = PjRtClient::cpu().unwrap();
        let buf = client.buffer_from_host_buffer(&[1i32, 2, 3, 4], &[2, 2], None).unwrap();
        let lit = buf.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(lit.to_vec::<f32>().is_err(), "type mismatch must be caught");
    }

    #[test]
    fn dim_mismatch_rejected() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.buffer_from_host_buffer(&[1.0f32; 3], &[2, 2], None).is_err());
    }

    #[test]
    fn compile_reports_stub() {
        let client = PjRtClient::cpu().unwrap();
        let err = client.compile(&XlaComputation).unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
