//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) into Send+Sync spec data. Compiled executables
//! are `!Send`, so the compile cache lives in the service thread's
//! [`crate::runtime::client::XlaContext`], not here.

use crate::core::error::{OtprError, Result};
use crate::util::minijson::Json;
use std::path::{Path, PathBuf};

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// Family: "phase_step", "cost_euclid", "cost_l1", "quantize",
    /// "sinkhorn_step".
    pub kind: String,
    pub n: usize,
    pub file: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// Registry over a manifest directory (pure data; Send + Sync).
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    pub specs: Vec<ArtifactSpec>,
    /// Sizes available, ascending.
    pub sizes: Vec<usize>,
}

impl ArtifactRegistry {
    /// Default artifact directory: `OTPR_ARTIFACTS` env or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("OTPR_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
    }

    pub fn open_default() -> Result<Self> {
        Self::open(&Self::default_dir())
    }

    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            OtprError::Artifact(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let json = Json::parse(&text).map_err(OtprError::Artifact)?;
        let mut specs = Vec::new();
        for a in json
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| OtprError::Artifact("manifest missing artifacts".into()))?
        {
            let get_str = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| OtprError::Artifact(format!("artifact missing {k}")))?
                    .to_string())
            };
            let names = |k: &str| -> Vec<String> {
                a.get(k)
                    .and_then(|v| v.as_arr())
                    .map(|arr| {
                        arr.iter().filter_map(|x| x.as_str().map(String::from)).collect()
                    })
                    .unwrap_or_default()
            };
            specs.push(ArtifactSpec {
                name: get_str("name")?,
                kind: get_str("kind")?,
                n: a.get("n")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| OtprError::Artifact("artifact missing n".into()))?,
                file: get_str("file")?,
                inputs: names("inputs"),
                outputs: names("outputs"),
            });
        }
        let mut sizes: Vec<usize> = json
            .get("sizes")
            .and_then(|v| v.as_arr())
            .map(|arr| arr.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default();
        sizes.sort_unstable();
        Ok(Self { dir: dir.to_path_buf(), specs, sizes })
    }

    /// Smallest artifact size that fits an instance of `n` (router bucket).
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.sizes.iter().copied().find(|&s| s >= n).ok_or_else(|| {
            OtprError::Artifact(format!(
                "no artifact bucket ≥ {n} (available: {:?})",
                self.sizes
            ))
        })
    }

    pub fn spec(&self, kind: &str, n: usize) -> Result<&ArtifactSpec> {
        self.specs
            .iter()
            .find(|s| s.kind == kind && s.n == n)
            .ok_or_else(|| OtprError::Artifact(format!("no artifact {kind}_{n} in manifest")))
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("otpr_art_test1");
        write_manifest(
            &dir,
            r#"{"version":1,"sizes":[256,512],"artifacts":[
                {"name":"phase_step_256","kind":"phase_step","n":256,
                 "file":"phase_step_256.hlo.txt","inputs":["cq"],"outputs":["ya"]}
            ]}"#,
        );
        let reg = ArtifactRegistry::open(&dir).unwrap();
        assert_eq!(reg.sizes, vec![256, 512]);
        let s = reg.spec("phase_step", 256).unwrap();
        assert_eq!(s.inputs, vec!["cq"]);
        assert!(reg.spec("phase_step", 123).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bucket_routing() {
        let dir = std::env::temp_dir().join("otpr_art_test2");
        write_manifest(&dir, r#"{"version":1,"sizes":[512,256,1024],"artifacts":[]}"#);
        let reg = ArtifactRegistry::open(&dir).unwrap();
        assert_eq!(reg.bucket_for(1).unwrap(), 256);
        assert_eq!(reg.bucket_for(256).unwrap(), 256);
        assert_eq!(reg.bucket_for(257).unwrap(), 512);
        assert_eq!(reg.bucket_for(1000).unwrap(), 1024);
        assert!(reg.bucket_for(5000).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_friendly() {
        let err = ArtifactRegistry::open(Path::new("/nonexistent/otpr")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
