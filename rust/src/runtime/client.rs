//! PJRT access layer.
//!
//! The `xla` crate's `PjRtClient`/`PjRtLoadedExecutable`/`PjRtBuffer` are
//! `!Send` (they hold `Rc`s over the C handles), so all device interaction
//! runs on one dedicated **service thread** ([`XlaService`]): callers ship
//! `'static + Send` closures in, the closure runs with an [`XlaContext`]
//! (client + compile cache), and only plain `Send` data (Vec<i32>, stats)
//! comes back. This serializes device work — faithful to the single-device
//! setup the paper's GPU implementation assumes — while the rest of the
//! coordinator stays multi-threaded.

use crate::core::error::{OtprError, Result};
use crate::runtime::artifact::ArtifactRegistry;
#[cfg(not(feature = "xla"))]
use crate::runtime::pjrt_stub as xla;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

/// State owned by the service thread.
pub struct XlaContext {
    pub client: xla::PjRtClient,
    pub registry: Arc<ArtifactRegistry>,
    cache: HashMap<String, Rc<xla::PjRtLoadedExecutable>>,
}

impl XlaContext {
    /// Load + compile (cached) the artifact `kind` at bucket size `n`.
    pub fn executable(&mut self, kind: &str, n: usize) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let spec = self.registry.spec(kind, n)?.clone();
        if let Some(exe) = self.cache.get(&spec.name) {
            return Ok(exe.clone());
        }
        let path = self.registry.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| OtprError::Artifact("bad path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.insert(spec.name.clone(), exe.clone());
        crate::log_debug!("compiled artifact {}", spec.name);
        Ok(exe)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.len()
    }

    /// Upload an i32 tensor. Uses `buffer_from_host_buffer` — NOT
    /// `buffer_from_host_literal`, whose buffers come back from `execute_b`
    /// with corrupted physical sizes in xla_extension 0.5.1.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}

/// Download a device buffer as Vec<i32>.
///
/// Goes through `to_literal_sync` — `copy_raw_to_host_sync` returns
/// "CopyRawToHost not implemented" on the 0.5.1 CPU client.
pub fn download_i32(buf: &xla::PjRtBuffer, len: usize) -> Result<Vec<i32>> {
    let lit = buf.to_literal_sync()?;
    let out = lit.to_vec::<i32>()?;
    debug_assert_eq!(out.len(), len);
    Ok(out)
}

/// Download a device buffer as Vec<f32>.
pub fn download_f32(buf: &xla::PjRtBuffer, len: usize) -> Result<Vec<f32>> {
    let lit = buf.to_literal_sync()?;
    let out = lit.to_vec::<f32>()?;
    debug_assert_eq!(out.len(), len);
    Ok(out)
}

/// Run a single-output executable on buffers, returning the output buffer.
/// All artifacts are lowered untupled with exactly one array result (see
/// python/compile/aot.py), so `outs[0][0]` is a plain feed-back-able buffer.
pub fn run1(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<xla::PjRtBuffer> {
    let mut outs = exe.execute_b(args)?;
    if outs.is_empty() || outs[0].is_empty() {
        return Err(OtprError::Runtime("executable produced no outputs".into()));
    }
    Ok(outs.remove(0).remove(0))
}

type ServiceJob = Box<dyn FnOnce(&mut XlaContext) + Send>;

/// Dedicated device thread; see module docs.
pub struct XlaService {
    tx: Sender<ServiceJob>,
}

impl XlaService {
    pub fn start(registry: Arc<ArtifactRegistry>) -> Result<Self> {
        let (tx, rx) = channel::<ServiceJob>();
        let (init_tx, init_rx) = channel::<std::result::Result<(), String>>();
        std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || {
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => c,
                    Err(e) => {
                        let _ = init_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                let _ = init_tx.send(Ok(()));
                let mut ctx = XlaContext { client, registry, cache: HashMap::new() };
                while let Ok(job) = rx.recv() {
                    job(&mut ctx);
                }
            })
            .map_err(|e| OtprError::Runtime(format!("spawn xla-service: {e}")))?;
        init_rx
            .recv()
            .map_err(|_| OtprError::Runtime("xla-service died during init".into()))?
            .map_err(OtprError::Runtime)?;
        Ok(Self { tx })
    }

    /// Run `f` on the service thread and wait for its result.
    pub fn call<T, F>(&self, f: F) -> Result<T>
    where
        T: Send + 'static,
        F: FnOnce(&mut XlaContext) -> Result<T> + Send + 'static,
    {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Box::new(move |ctx| {
                let _ = reply_tx.send(f(ctx));
            }))
            .map_err(|_| OtprError::Runtime("xla-service is down".into()))?;
        reply_rx.recv().map_err(|_| OtprError::Runtime("xla-service dropped the job".into()))?
    }
}

/// Registry + service bundle — the handle the rest of the crate passes
/// around (Send + Sync; all !Send state lives behind the service thread).
pub struct XlaRuntime {
    pub registry: Arc<ArtifactRegistry>,
    service: XlaService,
}

impl XlaRuntime {
    pub fn open(dir: &std::path::Path) -> Result<Arc<Self>> {
        let registry = Arc::new(ArtifactRegistry::open(dir)?);
        let service = XlaService::start(registry.clone())?;
        Ok(Arc::new(Self { registry, service }))
    }

    pub fn open_default() -> Result<Arc<Self>> {
        Self::open(&ArtifactRegistry::default_dir())
    }

    pub fn call<T, F>(&self, f: F) -> Result<T>
    where
        T: Send + 'static,
        F: FnOnce(&mut XlaContext) -> Result<T> + Send + 'static,
    {
        self.service.call(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ArtifactRegistry;

    fn empty_registry() -> Arc<ArtifactRegistry> {
        let dir = std::env::temp_dir().join("otpr_svc_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":2,"sizes":[],"artifacts":[]}"#,
        )
        .unwrap();
        Arc::new(ArtifactRegistry::open(&dir).unwrap())
    }

    #[test]
    fn service_roundtrips_buffers() {
        let svc = XlaService::start(empty_registry()).unwrap();
        let out = svc
            .call(|ctx| {
                let buf = ctx.upload_i32(&[1, 2, 3, 4, 5, 6], &[2, 3])?;
                download_i32(&buf, 6)
            })
            .unwrap();
        assert_eq!(out, vec![1, 2, 3, 4, 5, 6]);
        let out = svc
            .call(|ctx| {
                let buf = ctx.upload_f32(&[0.5, 1.5], &[2])?;
                download_f32(&buf, 2)
            })
            .unwrap();
        assert_eq!(out, vec![0.5, 1.5]);
    }

    #[test]
    fn service_survives_job_errors() {
        let svc = XlaService::start(empty_registry()).unwrap();
        let err = svc.call(|ctx| ctx.executable("nope", 1).map(|_| ())).unwrap_err();
        assert!(err.to_string().contains("no artifact"));
        // still alive
        let ok = svc.call(|_| Ok(42)).unwrap();
        assert_eq!(ok, 42);
    }

    #[test]
    fn calls_from_multiple_threads() {
        let svc = std::sync::Arc::new(XlaService::start(empty_registry()).unwrap());
        std::thread::scope(|s| {
            for t in 0..4 {
                let svc = svc.clone();
                s.spawn(move || {
                    let v = svc.call(move |_| Ok(t * 10)).unwrap();
                    assert_eq!(v, t * 10);
                });
            }
        });
    }
}
