//! Self-tests for the `otpr analyze` rule set (PR 6): one positive and one
//! negative case per rule, the in-source suppression grammar, rule scoping
//! by path, and the allowlist lifecycle (suppression, stale entries,
//! missing reasons) through the same `run()` entry the CLI gate uses.

use std::fs;
use std::path::PathBuf;

use otpr::exp::analyze::{
    analyze_source, run, Allowlist, CONTRACT_MARKER, SPARSE_CONTRACT_MARKER,
};

fn rules_of(rel: &str, src: &str) -> Vec<&'static str> {
    analyze_source(rel, src).into_iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------
// safety-comment (unscoped)
// ---------------------------------------------------------------------

#[test]
fn unsafe_without_safety_comment_is_flagged() {
    let src = "pub fn read(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n";
    let f = analyze_source("util/x.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, "safety-comment");
    assert_eq!(f[0].line, 2, "1-based line of the `unsafe` token");
}

#[test]
fn safety_comment_above_or_inline_suppresses() {
    let above = "pub fn read(p: *const u32) -> u32 {\n    // SAFETY: caller guarantees p is valid\n    unsafe { *p }\n}\n";
    assert!(rules_of("util/x.rs", above).is_empty());
    let inline = "pub fn read(p: *const u32) -> u32 {\n    unsafe { *p } // SAFETY: caller guarantees p is valid\n}\n";
    assert!(rules_of("util/x.rs", inline).is_empty());
    // an attribute between the comment and the keyword keeps the block contiguous
    let gapped = "// SAFETY: checked by the caller\n#[inline]\nunsafe fn f() {}\n";
    assert!(rules_of("util/x.rs", gapped).is_empty());
}

#[test]
fn unsafe_in_comments_and_strings_is_ignored() {
    let src = "// unsafe is discussed here, not used\npub fn f() -> &'static str {\n    \"unsafe\"\n}\n";
    assert!(rules_of("util/x.rs", src).is_empty());
}

// ---------------------------------------------------------------------
// kernel-cast (core/kernel/** + core/quantize.rs only)
// ---------------------------------------------------------------------

#[test]
fn bare_lossy_cast_in_kernel_scope_is_flagged() {
    let src = "pub fn f(v: u64) -> u32 {\n    v as u32\n}\n";
    let f = analyze_source("core/quantize.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, "kernel-cast");
    assert!(f[0].message.contains("as u32"), "{}", f[0].message);
    assert!(rules_of("core/kernel/arena.rs", src).contains(&"kernel-cast"));
}

#[test]
fn kernel_cast_scoping_annotation_and_widening_exemptions() {
    let src = "pub fn f(v: u64) -> u32 {\n    v as u32\n}\n";
    // same code outside the kernel scope: not this rule's business
    assert!(rules_of("solvers/x.rs", src).is_empty());
    // widening / same-width targets are exempt
    let widen = "pub fn f(v: u32) -> u64 {\n    v as u64\n}\n";
    assert!(rules_of("core/quantize.rs", widen).is_empty());
    // cast-ok with a reason suppresses; the tag may sit anywhere in the
    // contiguous comment block directly above the cast
    let ok = "pub fn f(v: u64) -> u32 {\n    // cast-ok: v is bounded by n, which fits u32\n    // (validated at construction)\n    v as u32\n}\n";
    assert!(rules_of("core/quantize.rs", ok).is_empty());
    // a bare tag with no reason does NOT suppress
    let bare = "pub fn f(v: u64) -> u32 {\n    // cast-ok:\n    v as u32\n}\n";
    assert!(rules_of("core/quantize.rs", bare).contains(&"kernel-cast"));
    // a blank line breaks the comment block: the tag no longer applies
    let gap = "pub fn f(v: u64) -> u32 {\n    // cast-ok: bounded\n\n    v as u32\n}\n";
    assert!(rules_of("core/quantize.rs", gap).contains(&"kernel-cast"));
}

// ---------------------------------------------------------------------
// float-eq (unscoped)
// ---------------------------------------------------------------------

#[test]
fn float_equality_is_flagged() {
    let lit = "pub fn f(x: f64) -> bool {\n    x == 0.0\n}\n";
    assert_eq!(rules_of("solvers/x.rs", lit), vec!["float-eq"]);
    let assoc = "pub fn f(m: f64) -> bool {\n    m != f64::NEG_INFINITY\n}\n";
    assert_eq!(rules_of("solvers/x.rs", assoc), vec!["float-eq"]);
}

#[test]
fn float_eq_annotation_and_non_float_compares() {
    let ok = "pub fn f(x: f64) -> bool {\n    // float-eq-ok: exact fold identity, not a tolerance check\n    x == 0.0\n}\n";
    assert!(rules_of("solvers/x.rs", ok).is_empty());
    let int = "pub fn f(x: u32) -> bool {\n    x == 10\n}\n";
    assert!(rules_of("solvers/x.rs", int).is_empty());
    // tuple field access is not a float literal
    let tuple = "pub fn f(a: (u32, u32), b: (u32, u32)) -> bool {\n    a.0 == b.0\n}\n";
    assert!(rules_of("solvers/x.rs", tuple).is_empty());
    // float text inside a string literal is not a comparison
    let instr = "pub fn f() -> &'static str {\n    \"x == 0.0\"\n}\n";
    assert!(rules_of("solvers/x.rs", instr).is_empty());
}

// ---------------------------------------------------------------------
// no-panic (api/core/solvers/coordinator/runtime/data only)
// ---------------------------------------------------------------------

#[test]
fn panics_in_library_solve_paths_are_flagged() {
    let unwrap = "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n";
    assert_eq!(rules_of("solvers/x.rs", unwrap), vec!["no-panic"]);
    let panic = "pub fn f() {\n    panic!(\"boom\");\n}\n";
    assert_eq!(rules_of("api/x.rs", panic), vec!["no-panic"]);
    let expect = "pub fn f(v: Option<u32>) -> u32 {\n    v.expect(\"present\")\n}\n";
    assert_eq!(rules_of("coordinator/x.rs", expect), vec!["no-panic"]);
}

#[test]
fn no_panic_scoping_annotation_and_test_mask() {
    let unwrap = "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n";
    // exp/ and util/ are harness code, out of scope
    assert!(rules_of("exp/x.rs", unwrap).is_empty());
    assert!(rules_of("util/x.rs", unwrap).is_empty());
    // panic-ok with a reason suppresses
    let ok = "pub fn f(v: Option<u32>) -> u32 {\n    // panic-ok: v is Some by construction two lines up\n    v.unwrap()\n}\n";
    assert!(rules_of("solvers/x.rs", ok).is_empty());
    // unwrap_or_else is the panic-free idiom, not a panic site
    let recover =
        "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap_or_else(|| 0)\n}\n";
    assert!(rules_of("solvers/x.rs", recover).is_empty());
    // #[cfg(test)] mod tests is exempt even in scoped files
    let tested = "pub fn ok() -> u32 {\n    1\n}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let v: Option<u32> = Some(1);\n        v.unwrap();\n    }\n}\n";
    assert!(rules_of("core/x.rs", tested).is_empty());
}

// ---------------------------------------------------------------------
// error-convention (core/** eps messages must name their provider)
// ---------------------------------------------------------------------

#[test]
fn eps_message_without_provider_is_flagged() {
    let src = "pub fn check(eps: f64) -> Result<(), String> {\n    if eps <= 0.0 {\n        return Err(format!(\"eps must be in (0, 1); got {eps}\"));\n    }\n    Ok(())\n}\n";
    assert_eq!(rules_of("core/quantize.rs", src), vec!["error-convention"]);
    // out of core/: the convention does not apply
    assert!(rules_of("solvers/x.rs", src).is_empty());
}

#[test]
fn eps_message_naming_provider_passes() {
    let same = "pub fn check(eps: f64, kind: &str) -> Result<(), String> {\n    if eps <= 0.0 {\n        return Err(format!(\"eps must be in (0, 1); provider={kind}\"));\n    }\n    Ok(())\n}\n";
    assert!(rules_of("core/quantize.rs", same).is_empty());
    // provider= within the next two lines also satisfies the rule
    let near = "pub fn check(eps: f64, kind: &str) -> Result<(), String> {\n    if eps <= 0.0 {\n        return Err(format!(\n            \"eps must be in (0, 1); \\\n             provider={kind}\"\n        ));\n    }\n    Ok(())\n}\n";
    assert!(rules_of("core/quantize.rs", near).is_empty());
}

// ---------------------------------------------------------------------
// contract-marker (the five kernel backend files)
// ---------------------------------------------------------------------

#[test]
fn worklist_fn_without_contract_marker_is_flagged() {
    let src = "impl Kernel {\n    fn run_phase(&mut self) {\n        self.accept_one(3);\n    }\n}\n";
    let f = analyze_source("core/kernel/scalar.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, "contract-marker");
    assert!(f[0].message.contains("run_phase"), "{}", f[0].message);
    // same code outside the backend files is not checked
    assert!(rules_of("core/kernel/mod.rs", src).is_empty());
}

#[test]
fn contract_marker_above_or_inside_the_fn_passes() {
    let above = format!(
        "impl Kernel {{\n    // {CONTRACT_MARKER} — staged per round.\n    fn run_phase(&mut self) {{\n        self.accept_one(3);\n    }}\n}}\n"
    );
    assert!(rules_of("core/kernel/chunked.rs", &above).is_empty());
    let inside = format!(
        "fn vector_sweep(&mut self) {{\n    // {CONTRACT_MARKER}\n    self.stage();\n}}\n"
    );
    assert!(rules_of("core/kernel/vector.rs", &inside).is_empty());
    // a fn that never touches the worklist needs no marker
    let clean = "fn helper(x: u32) -> u32 {\n    x + 1\n}\n";
    assert!(rules_of("core/kernel/vector.rs", clean).is_empty());
}

/// The hybrid backend (PR 7) is in the contract scope, and its sweep name
/// is a trigger: an unmarked fn fanning `hybrid_sweep` must be flagged.
#[test]
fn hybrid_backend_is_covered_by_the_contract_tripwire() {
    let src = "fn run_phase(&mut self) {\n    hybrid_sweep(view, acts, pl, ll, el, rs);\n}\n";
    let f = analyze_source("core/kernel/hybrid.rs", src);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "contract-marker");
    let marked = format!(
        "// {CONTRACT_MARKER}\nfn run_phase(&mut self) {{\n    hybrid_sweep(view, acts, pl, ll, el, rs);\n}}\n"
    );
    assert!(rules_of("core/kernel/hybrid.rs", &marked).is_empty());
}

// ---------------------------------------------------------------------
// contract-marker, sparse-plan flavor (arena.rs + transport.rs, PR 8)
// ---------------------------------------------------------------------

/// CSR extraction/assembly without the sparse fold-order marker is
/// flagged in both files of its scope, and the worklist marker does NOT
/// substitute — the two contracts are distinct invariants.
#[test]
fn csr_fn_without_sparse_contract_marker_is_flagged() {
    let extract = "pub fn plan(&self) -> UnitFlowCsr {\n    self.extract_plan_sparse()\n}\n";
    let f = analyze_source("core/kernel/arena.rs", extract);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "contract-marker");
    assert!(f[0].message.contains("plan"), "{}", f[0].message);
    assert!(f[0].message.contains(SPARSE_CONTRACT_MARKER), "{}", f[0].message);

    let assemble = "pub fn build(n: usize) -> Result<TransportPlan, String> {\n    TransportPlan::from_csr(n, n, vec![0; n + 1], Vec::new(), Vec::new())\n}\n";
    assert_eq!(rules_of("core/transport.rs", assemble), vec!["contract-marker"]);

    // the (different) worklist marker does not satisfy the sparse rule
    let wrong = format!("// {CONTRACT_MARKER}\n{extract}");
    assert_eq!(rules_of("core/kernel/arena.rs", &wrong), vec!["contract-marker"]);

    // same code outside the sparse scope is not checked
    assert!(rules_of("solvers/ot_push_relabel.rs", extract).is_empty());
    assert!(rules_of("core/kernel/mod.rs", extract).is_empty());
}

#[test]
fn sparse_contract_marker_above_or_inside_the_fn_passes() {
    let above = format!(
        "// {SPARSE_CONTRACT_MARKER}\npub fn plan(&self) -> UnitFlowCsr {{\n    self.extract_plan_sparse()\n}}\n"
    );
    assert!(rules_of("core/kernel/arena.rs", &above).is_empty());
    let inside = format!(
        "pub fn plan(&self) -> UnitFlowCsr {{\n    // {SPARSE_CONTRACT_MARKER}\n    self.extract_plan_sparse()\n}}\n"
    );
    assert!(rules_of("core/kernel/arena.rs", &inside).is_empty());
    // a fn that never touches CSR data needs no marker
    let clean = "pub fn nnz(&self) -> usize {\n    self.vals.len()\n}\n";
    assert!(rules_of("core/transport.rs", clean).is_empty());
}

// ---------------------------------------------------------------------
// allowlist lifecycle through run()
// ---------------------------------------------------------------------

struct TempTree(PathBuf);

impl TempTree {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!("otpr-analyze-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(dir.join("solvers")).unwrap();
        Self(dir)
    }
}

impl Drop for TempTree {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

const BAD_SOLVER: &str = "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n";

#[test]
fn run_reports_findings_and_allowlist_suppresses_them() {
    let tree = TempTree::new("suppress");
    fs::write(tree.0.join("solvers/bad.rs"), BAD_SOLVER).unwrap();

    let report = run(&tree.0, &Allowlist::empty()).unwrap();
    assert_eq!(report.files, 1);
    assert_eq!(report.suppressed, 0);
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].rule, "no-panic");
    assert_eq!(report.findings[0].file, "solvers/bad.rs");

    let allow = Allowlist::parse(
        "[[allow]]\nrule = \"no-panic\"\nfile = \"solvers/bad.rs\"\npattern = \"unwrap\"\nreason = \"exercise the suppression path in tests\"\n",
    )
    .unwrap();
    let report = run(&tree.0, &allow).unwrap();
    assert_eq!(report.suppressed, 1);
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}

#[test]
fn stale_allowlist_entries_are_themselves_findings() {
    let tree = TempTree::new("stale");
    fs::write(tree.0.join("solvers/clean.rs"), "pub fn f() -> u32 {\n    1\n}\n").unwrap();
    let allow = Allowlist::parse(
        "[[allow]]\nrule = \"no-panic\"\nfile = \"solvers/clean.rs\"\npattern = \"unwrap\"\nreason = \"nothing matches this any more\"\n",
    )
    .unwrap();
    let report = run(&tree.0, &allow).unwrap();
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].rule, "stale-allow");
    assert_eq!(report.findings[0].file, "analyze-allow.toml");
}

#[test]
fn allowlist_entries_without_reasons_are_rejected() {
    let tree = TempTree::new("noreason");
    fs::write(tree.0.join("solvers/bad.rs"), BAD_SOLVER).unwrap();
    let allow = Allowlist::parse(
        "[[allow]]\nrule = \"no-panic\"\nfile = \"solvers/bad.rs\"\npattern = \"unwrap\"\n",
    )
    .unwrap();
    let report = run(&tree.0, &allow).unwrap();
    // the suppression still applies, but the missing reason is a finding
    assert_eq!(report.suppressed, 1);
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].rule, "allow-missing-reason");
}

#[test]
fn report_json_carries_counts_and_findings() {
    let tree = TempTree::new("json");
    fs::write(tree.0.join("solvers/bad.rs"), BAD_SOLVER).unwrap();
    let report = run(&tree.0, &Allowlist::empty()).unwrap();
    let json = report.to_json().to_string();
    assert!(json.contains("\"findings\""), "{json}");
    assert!(json.contains("no-panic"), "{json}");
    let table = report.table();
    assert!(table.contains("solvers/bad.rs"), "{table}");
}

// ---------------------------------------------------------------------
// the committed tree itself stays gate-clean
// ---------------------------------------------------------------------

/// The in-repo equivalent of `otpr analyze --gate`: the committed sources
/// plus the committed allowlist must produce zero findings (and no stale
/// or reasonless allow entries). This keeps the gate honest even in
/// environments that run tests without the CLI step.
#[test]
fn committed_tree_is_gate_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let allow_path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("analyze-allow.toml");
    let allow = Allowlist::parse(&fs::read_to_string(&allow_path).unwrap()).unwrap();
    let report = run(&root, &allow).unwrap();
    assert!(
        report.findings.is_empty(),
        "committed tree has analyzer findings:\n{}",
        report.table()
    );
}
