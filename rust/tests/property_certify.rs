//! Property suite for the certification subsystem: quantization laws
//! (error ≤ 1 ε-unit per edge), the Lemma 3.1 lower bound never beating
//! the exact optimum, and end-to-end certificates verifying on random
//! instances for both coupling shapes. Runs at `OTPR_PROP_CASES` cases
//! (nightly CI drives it at 512).

use otpr::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
use otpr::core::duals::dual_lower_bound_units;
use otpr::core::kernel::{FlowKernel, ScalarKernel};
use otpr::core::{AssignmentInstance, CostMatrix, OtInstance, QuantizedCosts};
use otpr::data::workloads::random_simplex;
use otpr::prop_assert;
use otpr::solvers::hungarian;
use otpr::solvers::push_relabel::assignment_phase_cap;
use otpr::solvers::ssp_ot::SspExactOt;
use otpr::solvers::OtSolver;
use otpr::util::proptest_mini::{check, check_default, PropConfig};
use otpr::util::rng::Pcg32;

fn random_costs(rng: &mut Pcg32, n: usize) -> CostMatrix {
    CostMatrix::from_fn(n, n, |_, _| rng.next_f32())
}

/// Satellite: quantize→dequantize error is below one ε-unit on every edge
/// (`c̄ ≤ c < c̄ + ε_abs`), for random instances and random ε.
#[test]
fn prop_quantize_dequantize_error_at_most_one_unit() {
    check_default("quantize round-trip error", |rng| {
        let n = 2 + rng.next_below(15) as usize; // ≤ 16
        let eps = 0.02 + 0.6 * rng.next_f64();
        let costs = random_costs(rng, n);
        let q = QuantizedCosts::new(&costs, eps);
        for b in 0..n {
            for a in 0..n {
                let c = costs.at(b, a) as f64;
                let err = c - q.rounded(b, a);
                prop_assert!(err >= -1e-9, "rounded above original at ({b},{a}): {err}");
                prop_assert!(
                    err < q.eps_abs + 1e-9,
                    "error {err} exceeds one unit (eps_abs={}) at ({b},{a})",
                    q.eps_abs
                );
            }
        }
        Ok(())
    });
}

/// Satellite: the Lemma 3.1 dual lower bound, dequantized, never exceeds
/// the exact optimum on random n ≤ 16 instances.
#[test]
fn prop_dual_lower_bound_never_exceeds_exact_optimum() {
    check_default("dual lower bound vs exact", |rng| {
        let n = 2 + rng.next_below(15) as usize;
        let eps = [0.3, 0.15, 0.08][rng.next_below(3) as usize];
        let costs = random_costs(rng, n);
        let mut k = ScalarKernel::new();
        k.init(&costs, eps, None);
        k.run_to_termination(assignment_phase_cap(eps))?;
        let (_, exact, _, _) = hungarian::solve_exact(&costs).map_err(|e| e.to_string())?;
        let lb = dual_lower_bound_units(&k.duals()) as f64 * k.arena().q.eps_abs;
        prop_assert!(lb <= exact + 1e-9, "lb {lb} > exact {exact} (n={n}, eps={eps})");
        Ok(())
    });
}

/// End-to-end: every certified push-relabel assignment solve passes all
/// three certificate verdicts, and the certified lower bound really
/// bounds the Hungarian optimum from below.
#[test]
fn prop_assignment_certificates_verify() {
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    check_default("assignment certificates", |rng| {
        let n = 2 + rng.next_below(15) as usize;
        let eps = 0.05 + 0.5 * rng.next_f64();
        let costs = random_costs(rng, n);
        let inst = AssignmentInstance::new(costs).map_err(|e| e.to_string())?;
        let problem = Problem::Assignment(inst);
        let engine = if rng.next_below(2) == 0 { "native-seq" } else { "native-parallel" };
        let req = SolveRequest::new(eps).certify(true);
        let sol = registry
            .solve(engine, &config, &problem, &req)
            .map_err(|e| e.to_string())?;
        let cert = sol.certificate.as_ref().ok_or("certificate missing")?;
        prop_assert!(cert.primal_ok, "{engine} primal: {:?}", cert.detail);
        prop_assert!(cert.dual_ok == Some(true), "{engine} dual: {:?}", cert.detail);
        prop_assert!(
            cert.gap_ok(),
            "{engine} gap {:?} > bound {} (n={n}, eps={eps})",
            cert.gap,
            cert.bound
        );
        let (_, exact, _, _) =
            hungarian::solve_exact(problem.costs()).map_err(|e| e.to_string())?;
        let lb = cert.dual_lower_bound.ok_or("missing dual lower bound")?;
        prop_assert!(lb <= exact + 1e-9, "certified lb {lb} > exact {exact}");
        Ok(())
    });
}

/// End-to-end for the OT generalization: exported cluster duals verify,
/// the transport lower bound holds against the exact OT oracle, and the
/// Theorem 4.2 additive bound is met.
#[test]
fn prop_ot_certificates_verify() {
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    // Scales with OTPR_PROP_CASES like the rest of the suite, capped at
    // 128 because every case also runs the exact SSP oracle.
    let cases = PropConfig::default().cases.min(128);
    check(
        "ot certificates",
        &PropConfig { cases, ..Default::default() },
        |rng| {
            let n = 3 + rng.next_below(8) as usize; // ≤ 10
            let costs = random_costs(rng, n);
            let demand = random_simplex(n, rng);
            let supply = random_simplex(n, rng);
            let inst = OtInstance::new(costs, demand, supply).map_err(|e| e.to_string())?;
            let problem = Problem::Ot(inst.clone());
            let eps = [0.4, 0.25, 0.15][rng.next_below(3) as usize];
            let req = SolveRequest::new(eps).certify(true);
            let sol = registry
                .solve("native-seq", &config, &problem, &req)
                .map_err(|e| e.to_string())?;
            let cert = sol.certificate.as_ref().ok_or("certificate missing")?;
            prop_assert!(cert.primal_ok, "primal: {:?} (n={n}, eps={eps})", cert.detail);
            prop_assert!(cert.dual_ok == Some(true), "dual: {:?}", cert.detail);
            prop_assert!(
                cert.gap_ok(),
                "gap {:?} > bound {} (n={n}, eps={eps})",
                cert.gap,
                cert.bound
            );
            let exact = SspExactOt::default()
                .solve_ot(&inst, 0.0)
                .map_err(|e| e.to_string())?
                .cost;
            let lb = cert.dual_lower_bound.ok_or("missing dual lower bound")?;
            prop_assert!(lb <= exact + 1e-9, "certified lb {lb} > exact OT cost {exact}");
            let budget = eps * inst.costs.max() as f64;
            prop_assert!(
                sol.cost <= exact + budget + 1e-9,
                "Theorem 4.2 violated: {} > {exact} + {budget}",
                sol.cost
            );
            Ok(())
        },
    );
}
