//! Sparse transport plans end-to-end (PR 8): kernel OT solves come back
//! as canonical-order CSR, cancelled solves as a lazy product coupling,
//! and both are **bit-identical** to the dense slab they replace —
//! identical cost folds, identical marginals, identical certificates —
//! while the resident plan state drops from O(n²) to O(nnz) / O(n).
//!
//! Covers the PR-8 acceptance gates:
//! * dense-vs-CSR equivalence on the golden OT corpus for all six kernel
//!   engines (dense and implicit problems, warm variants included);
//! * a property sweep asserting extracted support compactness on
//!   feasible solves (≤ θ + O(nb+na) entries, never the dense slab);
//! * the n=4096 allocation-free cancellation regression (lazy product,
//!   O(nb+na) plan bytes — the old code allocated the n² slab even for
//!   a solve that never ran);
//! * the n=4096 implicit OT solve with O(n) plan bytes on top of the
//!   PR-5 no-cost-slab guarantee;
//! * `matching_to_plan` / `from_csr` construction contracts.

use otpr::api::{CancelToken, Problem, SolveRequest, SolverConfig, SolverRegistry};
use otpr::core::certify::certify;
use otpr::core::transport::TransportPlan;
use otpr::data::workloads::{Workload, GOLDEN_SPECS};
use otpr::prop_assert;
use otpr::solvers::matching_to_plan;
use otpr::util::proptest_mini::{check, PropConfig};

const KERNEL_ENGINES: [&str; 6] = [
    "native-seq",
    "native-parallel",
    "native-vector",
    "native-hybrid",
    "native-seq-warm",
    "native-vector-warm",
];

/// θ as the mass-scaling layer computes it for an overall-ε OT request
/// (`ScaledOtInstance::from_parts` with eps_mass = the request ε).
fn theta(nb: usize, na: usize, eps: f64) -> f64 {
    4.0 * nb.max(na) as f64 / eps
}

/// Rebuild `plan` as a dense-slab twin through the random-access reader,
/// then assert every fold the old dense representation answered is
/// bit-identical on the compact one: cost, both marginals, total mass.
fn assert_folds_match_dense_twin(plan: &TransportPlan, costs: &otpr::core::cost::CostMatrix) {
    let (nb, na) = (costs.nb, costs.na);
    let mut twin = TransportPlan::zeros(nb, na);
    for b in 0..nb {
        for a in 0..na {
            let v = plan.at(b, a);
            if v != 0.0 {
                twin.add(b, a, v);
            }
        }
    }
    assert_eq!(twin.repr_kind(), "dense");
    // The CSR fold skips only exact +0.0 terms of a non-negative sum, so
    // every aggregate must agree to the bit, not to a tolerance.
    assert_eq!(plan.cost(costs).to_bits(), twin.cost(costs).to_bits(), "cost fold diverged");
    assert_eq!(plan.supply_marginal(), twin.supply_marginal(), "supply marginal diverged");
    assert_eq!(plan.demand_marginal(), twin.demand_marginal(), "demand marginal diverged");
    assert_eq!(plan.total_mass().to_bits(), twin.total_mass().to_bits(), "total mass diverged");
    assert_eq!(plan.support_size(), twin.support_size(), "support count diverged");
}

/// The acceptance sweep: every golden OT case through every kernel
/// engine, dense and implicit problems — the plan arrives in CSR form,
/// dense-vs-implicit CSR triplets are byte-identical, every fold matches
/// a densified twin bit-for-bit, and certificates still pass.
#[test]
fn golden_corpus_csr_plans_identical_across_kernel_engines() {
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    for spec in GOLDEN_SPECS {
        let Some((supply, demand)) = spec.masses() else {
            continue; // assignment cases answer with a matching, not a plan
        };
        let costs = spec.costs();
        let dense_p = Problem::ot(costs.clone(), demand.clone(), supply.clone()).unwrap();
        let implicit_p = Problem::implicit_ot(spec.generated(), demand, supply).unwrap();
        for engine in KERNEL_ENGINES {
            for eps in [0.3, 0.1] {
                let label = format!("{} × {engine} eps={eps}", spec.name);
                let req = SolveRequest::new(eps);
                let d = registry.solve(engine, &config, &dense_p, &req).unwrap();
                let i = registry.solve(engine, &config, &implicit_p, &req).unwrap();
                let (dp, ip) = (d.plan().unwrap(), i.plan().unwrap());
                assert_eq!(dp.repr_kind(), "csr", "{label}: dense problem plan repr");
                assert_eq!(ip.repr_kind(), "csr", "{label}: implicit problem plan repr");
                // byte-identity of the whole triplet, not just the folds
                assert_eq!(dp.csr_view(), ip.csr_view(), "{label}: CSR triplets differ");
                assert_eq!(d.duals, i.duals, "{label}: duals differ");
                assert_eq!(d.cost.to_bits(), i.cost.to_bits(), "{label}: costs differ");
                assert_folds_match_dense_twin(dp, &costs);
                // memory accounting flows through to the solve stats
                assert_eq!(d.stats.plan_state_bytes, dp.state_bytes(), "{label}: stats bytes");
                assert_eq!(i.stats.plan_state_bytes, ip.state_bytes(), "{label}: stats bytes");
                for (sol, p) in [(&d, &dense_p), (&i, &implicit_p)] {
                    let cert = certify(p, sol, &req);
                    assert!(cert.ok(), "{label}: {}", cert.summary());
                }
            }
        }
    }
}

/// Property: extracted support is compact on feasible solves. Every CSR
/// entry comes from a live arena edge (≥ 1 of ≤ θ supply units), the
/// completion cursor only moves forward, and sub-unit residuals land on
/// existing capacity — so nnz stays O(θ + nb + na), far under the n²
/// slab. (The na+nb−1 vertex-form bound does *not* apply: push-relabel
/// flows are not extreme points, which is why the assert uses θ.)
#[test]
fn prop_kernel_ot_plans_have_compact_support() {
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    check(
        "kernel OT plans stay compact",
        &PropConfig { cases: 10, ..Default::default() },
        |rng| {
            let n = 48 + rng.next_below(49) as usize;
            let seed = rng.next_u64();
            let eps = [0.5, 0.7, 0.9][rng.next_below(3) as usize];
            let engine = KERNEL_ENGINES[rng.next_below(6) as usize];
            let inst = Workload::Fig1 { n }.ot_with_random_masses(seed);
            let (supply, demand) = (inst.supply.clone(), inst.demand.clone());
            let problem = Problem::Ot(inst);
            let sol = registry
                .solve(engine, &config, &problem, &SolveRequest::new(eps))
                .map_err(|e| e.to_string())?;
            let plan = sol.plan().expect("OT answers with a plan");
            prop_assert!(plan.repr_kind() == "csr", "repr={} ({engine})", plan.repr_kind());
            let th = theta(n, n, eps);
            // kernel edges ≤ θ, completion ≤ nb+na, residual fill gets
            // generous slack — and in all cases nowhere near the slab
            let bound = th.ceil() as usize + 4 * (2 * n);
            let nnz = plan.support_size();
            prop_assert!(
                nnz <= bound,
                "support {nnz} > θ+slack bound {bound} (n={n}, eps={eps}, seed={seed}, {engine})"
            );
            prop_assert!(
                nnz < n * n / 2,
                "support {nnz} not compact vs dense {} (n={n}, seed={seed})",
                n * n
            );
            prop_assert!(
                plan.state_bytes() < (n * n * 8) as u64,
                "plan bytes {} ≥ dense slab (n={n}, seed={seed}, {engine})",
                plan.state_bytes()
            );
            plan.check(&supply, &demand, 2.0 / th + 1e-9)
                .map_err(|e| format!("{e} (n={n}, eps={eps}, seed={seed}, {engine})"))?;
            Ok(())
        },
    );
}

/// The allocation-free cancellation regression (satellite 1): a solve
/// cancelled before phase 0 at n=4096 answers with the lazy ν⊗μ product
/// plan — O(nb+na) resident bytes. The pre-PR-8 representation dense-
/// allocated the product into an n²·8 = 134 MB slab just to throw it at
/// a caller who asked to stop.
#[test]
fn n4096_cancelled_ot_plan_stays_lazy_product() {
    let n = 4096usize;
    let (costs, demand, supply) =
        Workload::Fig1 { n }.implicit_ot_with_random_masses(7).expect("fig1 implicit");
    let problem = Problem::implicit_ot(costs, demand, supply).unwrap();
    let token = CancelToken::new();
    token.cancel();
    let req = SolveRequest::new(0.25).with_cancel(token);
    let registry = SolverRegistry::with_defaults();
    let sol = registry.solve("native-vector", &SolverConfig::default(), &problem, &req).unwrap();
    assert!(sol.is_cancelled());
    assert_eq!(sol.stats.phases, 0, "cancelled before any phase ran");
    let plan = sol.plan().expect("cancelled OT still answers with a feasible coupling");
    assert_eq!(plan.repr_kind(), "product");
    // exactly the two marginal vectors — nothing n²-shaped anywhere
    let lazy_bytes = ((n + n) * 8) as u64;
    assert_eq!(plan.state_bytes(), lazy_bytes);
    assert_eq!(sol.stats.plan_state_bytes, lazy_bytes);
    assert!(sol.cost.is_finite() && sol.cost >= 0.0, "priced by streaming, no slab");
}

/// The dense-problem twin of the regression above: the phase-0 branch in
/// `drive_ot_src` ships the identical lazy shape whichever cost
/// representation backs the solve, and the cost it reports is the exact
/// dense product-fold value.
#[test]
fn cancelled_dense_ot_plan_matches_product_fold() {
    let inst = Workload::Fig1 { n: 64 }.ot_with_random_masses(3);
    let (nb, na) = (inst.costs.nb, inst.costs.na);
    let expected = TransportPlan::product(&inst.supply, &inst.demand).cost(&inst.costs);
    let problem = Problem::Ot(inst);
    let token = CancelToken::new();
    token.cancel();
    let req = SolveRequest::new(0.3).with_cancel(token);
    let registry = SolverRegistry::with_defaults();
    let sol = registry.solve("native-seq", &SolverConfig::default(), &problem, &req).unwrap();
    assert!(sol.is_cancelled());
    let plan = sol.plan().unwrap();
    assert_eq!(plan.repr_kind(), "product");
    assert_eq!(plan.state_bytes(), ((nb + na) * 8) as u64);
    assert_eq!(sol.stats.plan_state_bytes, plan.state_bytes());
    assert_eq!(sol.cost.to_bits(), expected.to_bits(), "streamed pricing == dense fold");
}

/// The PR-8 memory wall, in-process: an n=4096 implicit OT solve holds
/// the O(n²/8) block-min cache as its *only* quadratic state (PR 5) and
/// now returns an O(n) CSR plan instead of the 134 MB dense slab.
#[test]
fn n4096_implicit_ot_solves_with_sparse_plan() {
    let n = 4096usize;
    // overall ε = 0.75 keeps the phase count debug-runtime-friendly,
    // mirroring the n=4096 assignment precedent in implicit_costs.rs
    let eps = 0.75;
    let (costs, demand, supply) =
        Workload::Fig1 { n }.implicit_ot_with_random_masses(42).expect("fig1 implicit");
    let (s_check, d_check) = (supply.clone(), demand.clone());
    let problem = Problem::implicit_ot(costs, demand, supply).unwrap();
    let registry = SolverRegistry::with_defaults();
    let sol = registry
        .solve("native-vector", &SolverConfig::default(), &problem, &SolveRequest::new(eps))
        .expect("implicit n=4096 OT solve");
    // cost side: still exactly the block-min cache (nb × na_padded/8 i32s)
    assert_eq!(sol.stats.cost_state_bytes, (n * (n / 8) * 4) as u64);
    // plan side: CSR with provably-bounded support
    let plan = sol.plan().unwrap();
    assert_eq!(plan.repr_kind(), "csr");
    let th = theta(n, n, eps);
    assert!(
        plan.support_size() <= th.ceil() as usize + 4 * (2 * n),
        "support {} exceeds the θ bound",
        plan.support_size()
    );
    let dense_slab = (n * n * 8) as u64;
    assert_eq!(sol.stats.plan_state_bytes, plan.state_bytes());
    assert!(
        sol.stats.plan_state_bytes < 1_000_000,
        "plan is not O(n): {} bytes vs {} dense",
        sol.stats.plan_state_bytes,
        dense_slab
    );
    plan.check(&s_check, &d_check, 2.0 / th + 1e-9).expect("feasible marginals");
    assert!(sol.cost.is_finite() && sol.cost >= 0.0);
}

/// `matching_to_plan` builds straight into CSR: ≤ 1 entry per supply row,
/// uniform 1/n mass, folds bit-identical to its densified twin.
#[test]
fn matching_to_plan_is_compact_csr() {
    let registry = SolverRegistry::with_defaults();
    let inst = Workload::Fig1 { n: 24 }.assignment(5);
    let costs = inst.costs.clone();
    let problem = Problem::Assignment(inst);
    let sol = registry
        .solve("native-seq", &SolverConfig::default(), &problem, &SolveRequest::new(0.2))
        .unwrap();
    let m = sol.matching().unwrap();
    assert!(m.is_perfect());
    let plan = matching_to_plan(m);
    assert_eq!(plan.repr_kind(), "csr");
    let (row_ptr, _, vals) = plan.csr_view().unwrap();
    assert_eq!(plan.support_size(), m.nb(), "one entry per matched supply");
    for b in 0..m.nb() {
        assert!(row_ptr[b + 1] - row_ptr[b] <= 1, "row {b} has multiple entries");
    }
    let unit = 1.0 / m.nb() as f64;
    assert!(vals.iter().all(|&v| v == unit), "uniform mass per matched edge");
    assert_folds_match_dense_twin(&plan, &costs);
    plan.check(&vec![unit; m.nb()], &vec![unit; m.na()], 1e-12).unwrap();
}

/// `from_csr` refuses anything that would break the canonical-order
/// contract the bit-identical folds rely on.
#[test]
fn from_csr_rejects_non_canonical_input() {
    // columns out of order within a row
    let err = TransportPlan::from_csr(1, 3, vec![0, 2], vec![2, 0], vec![0.5, 0.5]);
    assert!(err.unwrap_err().contains("strictly ascending"));
    // duplicate column (not strictly ascending either)
    let err = TransportPlan::from_csr(1, 3, vec![0, 2], vec![1, 1], vec![0.5, 0.5]);
    assert!(err.unwrap_err().contains("strictly ascending"));
    // column out of bounds
    let err = TransportPlan::from_csr(1, 2, vec![0, 1], vec![5], vec![1.0]);
    assert!(err.unwrap_err().contains("out of bounds"));
    // row_ptr shape mismatches
    let err = TransportPlan::from_csr(2, 2, vec![0, 1], vec![0], vec![1.0]);
    assert!(err.unwrap_err().contains("row_ptr len"));
    let err = TransportPlan::from_csr(1, 2, vec![0, 2], vec![0], vec![1.0]);
    assert!(err.unwrap_err().contains("end at nnz"));
    // negative / non-finite values
    let err = TransportPlan::from_csr(1, 2, vec![0, 1], vec![0], vec![-0.5]);
    assert!(err.unwrap_err().contains("finite non-negative"));
    let err = TransportPlan::from_csr(1, 2, vec![0, 1], vec![0], vec![f64::NAN]);
    assert!(err.unwrap_err().contains("finite non-negative"));
    // and the happy path round-trips
    let plan = TransportPlan::from_csr(2, 2, vec![0, 1, 2], vec![0, 1], vec![0.5, 0.5]).unwrap();
    assert_eq!(plan.at(0, 0), 0.5);
    assert_eq!(plan.at(0, 1), 0.0);
    assert_eq!(plan.support_size(), 2);
}

/// The product repr is lazy until a caller *forces* the slab — and the
/// byte accounting reports the forced cache honestly.
#[test]
fn product_plan_materializes_only_on_demand() {
    let supply = vec![0.25, 0.75];
    let demand = vec![0.5, 0.3, 0.2];
    let plan = TransportPlan::product(&supply, &demand);
    assert_eq!(plan.repr_kind(), "product");
    assert_eq!(plan.state_bytes(), ((2 + 3) * 8) as u64);
    assert_eq!(plan.at(1, 0), 0.75 * 0.5);
    assert_eq!(plan.supply_marginal(), vec![0.25, 0.75]);
    // forcing the dense view allocates the cache — and the accounting
    // grows by exactly the nb·na slab while the repr stays compact
    let slab = plan.as_slice().to_vec();
    assert_eq!(slab.len(), 6);
    assert_eq!(plan.repr_kind(), "product");
    assert_eq!(plan.state_bytes(), ((2 + 3) * 8 + 2 * 3 * 8) as u64);
    let twin = TransportPlan::product(&supply, &demand);
    assert_eq!(twin.cost_with(|b, a| (b + a) as f64).to_bits(), {
        let mut sum = 0.0;
        for b in 0..2 {
            for a in 0..3 {
                sum += slab[b * 3 + a] * (b + a) as f64;
            }
        }
        sum.to_bits()
    });
}
