//! Property tests for the `otpr::api` surface: `matching_to_plan`
//! marginal/cost identities and `SolveRequest` cancellation semantics.

use otpr::api::{CancelToken, Problem, SolveRequest, SolverConfig, SolverRegistry};
use otpr::data::workloads::Workload;
use otpr::prop_assert;
use otpr::solvers::matching_to_plan;
use otpr::util::proptest_mini::{check, check_default, PropConfig};
use otpr::util::rng::Pcg32;

/// A uniformly random perfect matching on n vertices (Fisher–Yates).
fn random_perfect_matching(n: usize, rng: &mut Pcg32) -> otpr::core::Matching {
    let mut perm: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = rng.next_below((i + 1) as u32) as usize;
        perm.swap(i, j);
    }
    let mut m = otpr::core::Matching::empty(n, n);
    for (b, &a) in perm.iter().enumerate() {
        m.link(b, a);
    }
    m
}

#[test]
fn matching_to_plan_marginals_sum_to_one() {
    check_default("matching_to_plan marginals", |rng| {
        let n = 1 + rng.next_below(24) as usize;
        let m = random_perfect_matching(n, rng);
        let plan = matching_to_plan(&m);
        let unit = 1.0 / n as f64;
        // every row and column marginal is exactly 1/n; totals sum to 1
        for (b, &row) in plan.supply_marginal().iter().enumerate() {
            prop_assert!((row - unit).abs() < 1e-12, "row {b} marginal {row} != {unit} (n={n})");
        }
        for (a, &col) in plan.demand_marginal().iter().enumerate() {
            prop_assert!((col - unit).abs() < 1e-12, "col {a} marginal {col} != {unit} (n={n})");
        }
        prop_assert!(
            (plan.total_mass() - 1.0).abs() < 1e-9,
            "total mass {} != 1 (n={n})",
            plan.total_mass()
        );
        prop_assert!(plan.support_size() == n, "support {} != n={n}", plan.support_size());
        Ok(())
    });
}

#[test]
fn matching_to_plan_cost_is_matching_cost_over_n() {
    check_default("matching_to_plan cost identity", |rng| {
        let n = 1 + rng.next_below(20) as usize;
        let costs = Workload::RandomCosts { n }.costs(rng.next_u64());
        let m = random_perfect_matching(n, rng);
        let plan = matching_to_plan(&m);
        let plan_cost = plan.cost(&costs);
        let match_cost = m.cost(&costs);
        prop_assert!(
            (plan_cost - match_cost / n as f64).abs() < 1e-9,
            "plan cost {plan_cost} != matching cost {match_cost} / {n}"
        );
        Ok(())
    });
}

/// Batch-path satellite: `solve_many` must be *observationally identical*
/// to solving each problem alone — same couplings, same costs, marginals
/// preserved — while reusing one kernel arena across same-shape items.
#[test]
fn solve_many_matches_per_item_solves_and_reuses_arena() {
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    check(
        "solve_many == per-item",
        &PropConfig { cases: 12, ..Default::default() },
        |rng| {
            let k = 3 + rng.next_below(6) as usize; // 3..=8 instances
            let eps = 0.1 + 0.3 * rng.next_f64();
            let ot_kind = rng.next_below(2) == 1;
            let n = 6 + rng.next_below(10) as usize;
            let problems: Vec<Problem> = (0..k)
                .map(|i| {
                    let seed = rng.next_u64().wrapping_add(i as u64);
                    if ot_kind {
                        Problem::Ot(Workload::Fig1 { n }.ot_with_random_masses(seed))
                    } else {
                        Problem::Assignment(Workload::RandomCosts { n }.assignment(seed))
                    }
                })
                .collect();
            let engine = if rng.next_below(2) == 0 { "native-seq" } else { "native-parallel" };
            let req = SolveRequest::new(eps);
            let report = req
                .solve_many(&registry, engine, &config, &problems)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                report.reuse_hits == k as u64 - 1,
                "{engine}: {} reuse hits for {k} same-shape instances",
                report.reuse_hits
            );
            for (i, (p, r)) in problems.iter().zip(&report.results).enumerate() {
                let batched = r.as_ref().map_err(|e| e.to_string())?;
                let single = registry.solve(engine, &config, p, &req).map_err(|e| e.to_string())?;
                prop_assert!(
                    (batched.cost - single.cost).abs() < 1e-12,
                    "{engine} item {i}: batched cost {} != single {}",
                    batched.cost,
                    single.cost
                );
                match (batched.plan(), single.plan()) {
                    (Some(bp), Some(sp)) => {
                        prop_assert!(bp.as_slice() == sp.as_slice(), "{engine} item {i}: plans differ");
                        // marginals preserved: the batched plan is feasible
                        // for its own instance
                        let inst = p.as_ot().expect("ot problem");
                        let theta = 4.0 * inst.n() as f64 / eps;
                        bp.check(&inst.supply, &inst.demand, 2.0 / theta + 1e-9)?;
                    }
                    (None, None) => {
                        prop_assert!(
                            batched.matching() == single.matching(),
                            "{engine} item {i}: matchings differ"
                        );
                        prop_assert!(
                            batched.matching().unwrap().is_perfect(),
                            "{engine} item {i}: batched matching imperfect"
                        );
                    }
                    _ => return Err(format!("{engine} item {i}: coupling shapes differ")),
                }
            }
            Ok(())
        },
    );
}

#[test]
fn cancelled_solve_returns_within_one_phase_and_notes_it() {
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    // Pre-cancelled token: every engine that honors control must stop
    // before running a full phase and must say "cancelled" in its notes.
    check(
        "pre-cancelled request stops within one phase",
        &PropConfig { cases: 12, seed: 0xAB },
        |rng| {
            let n = 8 + rng.next_below(40) as usize;
            let eps = 0.05 + 0.3 * rng.next_f64();
            let (problem, engine) = if rng.next_below(2) == 0 {
                (Problem::Assignment(Workload::RandomCosts { n }.assignment(rng.next_u64())), {
                    if rng.next_below(2) == 0 { "native-seq" } else { "native-parallel" }
                })
            } else {
                (
                    Problem::Ot(Workload::Fig1 { n: n.min(16) }.ot_with_random_masses(rng.next_u64())),
                    "native-seq",
                )
            };
            let token = CancelToken::new();
            token.cancel();
            let req = SolveRequest::new(eps).with_cancel(token);
            let sol = solvers
                .solve(engine, &config, &problem, &req)
                .map_err(|e| format!("cancelled solve must not error: {e}"))?;
            prop_assert!(
                sol.is_cancelled(),
                "{engine} (n={n}) missing cancelled note: {:?}",
                sol.stats.notes
            );
            prop_assert!(
                sol.stats.phases <= 1,
                "{engine} ran {} phases after cancellation",
                sol.stats.phases
            );
            Ok(())
        },
    );
}

#[test]
fn mid_solve_cancellation_stops_at_phase_boundary() {
    // Cancel from inside the observer after the first phase: the solver
    // must not run to termination.
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let problem = Problem::Assignment(Workload::Fig1 { n: 200 }.assignment(7));
    let token = CancelToken::new();
    let tripwire = token.clone();
    let req = SolveRequest::new(0.01)
        .raw_eps()
        .with_cancel(token)
        .with_observer(move |p| {
            if p.phase >= 1 {
                tripwire.cancel();
            }
        });
    let sol = solvers.solve("native-seq", &config, &problem, &req).unwrap();
    assert!(sol.is_cancelled());
    assert!(sol.stats.phases <= 2, "stopped late: {} phases", sol.stats.phases);
    // a full run at this ε takes far more phases — sanity-check that
    let full = solvers
        .solve("native-seq", &config, &problem, &SolveRequest::new(0.01).raw_eps())
        .unwrap();
    assert!(full.stats.phases > 2, "baseline only took {} phases", full.stats.phases);
    assert!(!full.is_cancelled());
}

#[test]
fn sinkhorn_honors_cancellation() {
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let problem = Problem::Assignment(Workload::Fig1 { n: 24 }.assignment(3));
    let token = CancelToken::new();
    token.cancel();
    let req = SolveRequest::new(0.1).with_cancel(token);
    let sol = solvers.solve("sinkhorn-native", &config, &problem, &req).unwrap();
    assert!(sol.is_cancelled());
    assert_eq!(sol.stats.phases, 0, "no sweeps after pre-cancellation");
    // the rounded iterate is still an exactly feasible plan
    let ot = problem.to_ot_instance().unwrap();
    sol.plan().unwrap().check(&ot.supply, &ot.demand, 1e-6).unwrap();
}
