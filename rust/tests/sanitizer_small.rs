//! Size-shrunk kernel tests for the sanitizer CI jobs (PR 6).
//!
//! * `miri_*` — tiny-n variants of the arena init / rescale / warm_reinit,
//!   implicit-row-LRU, quantize, and provider tests. They run in normal
//!   `cargo test` too (they are fast), but their real job is
//!   `cargo +nightly miri test --test sanitizer_small -- miri_`, where the
//!   full-size suites would be prohibitively slow. The phase-boundary
//!   `debug_assert!` invariants in `KernelArena` fire for free here.
//! * `tsan_*` — the Chunked-vs-Scalar and Hybrid-vs-Scalar byte-identity
//!   contracts at ≥4 sweep threads (dense + implicit + OT masses), the
//!   suite the ThreadSanitizer job (`RUSTFLAGS=-Zsanitizer=thread`)
//!   drives. Any data race in the propose fan-out is a determinism bug
//!   before it is a safety bug — TSan catches it at the memory level,
//!   the asserts at the result level.
//!
//! See "Correctness tooling" in `rust/src/api/README.md` for how to run
//! both locally.

use otpr::core::duals::check_feasible;
use otpr::core::kernel::{ChunkedKernel, FlowKernel, HybridKernel, ScalarKernel, VectorKernel};
use otpr::core::provider::{Costs, GeneratedCosts};
use otpr::core::quantize::QuantizedCosts;
use otpr::core::CostMatrix;
use otpr::util::rng::Pcg32;

fn random_costs(n: usize, seed: u64) -> CostMatrix {
    let mut rng = Pcg32::new(seed);
    CostMatrix::from_fn(n, n, |_, _| rng.next_f32())
}

fn generated_mirror(dense: &CostMatrix, n: usize) -> Costs {
    let grid = dense.clone();
    Costs::generated(GeneratedCosts::new(n, n, move |b, a| grid.at(b, a)).unwrap())
}

// ---------------------------------------------------------------------
// miri_* — small-n arena/quantize/provider coverage
// ---------------------------------------------------------------------

#[test]
fn miri_arena_init_and_solve_small() {
    let costs = random_costs(8, 3);
    let mut k = ScalarKernel::new();
    k.init(&costs, 0.25, None);
    k.run_to_termination(10_000).unwrap();
    k.check_invariants().unwrap();
    let m = k.extract_matching();
    m.check_consistent().unwrap();
    assert!(k.arena().free_units() <= k.arena().threshold());
    let y = k.duals();
    assert!(y.yb.iter().all(|&v| v >= 0));
    assert!(y.ya.iter().all(|&v| v <= 0));
}

#[test]
fn miri_rescale_small() {
    let costs = random_costs(8, 5);
    let mut k = ScalarKernel::new();
    k.init(&costs, 0.4, None);
    k.run_to_termination(10_000).unwrap();
    k.arena_mut().rescale(&costs, 0.2);
    k.check_invariants().unwrap();
    k.run_to_termination(10_000).unwrap();
    k.check_invariants().unwrap();
    assert!(k.arena().free_units() <= k.arena().threshold());
    assert_eq!(k.arena().rescales, 1);
    check_feasible(&k.arena().q, &k.extract_matching(), &k.duals()).unwrap();
}

#[test]
fn miri_warm_reinit_small() {
    let (c1, c2) = (random_costs(8, 1), random_costs(8, 2));
    let mut k = ScalarKernel::new();
    k.init(&c1, 0.25, None);
    k.run_to_termination(10_000).unwrap();
    k.arena_mut().warm_reinit(&c2, 0.25, None);
    k.check_invariants().unwrap();
    k.run_to_termination(10_000).unwrap();
    let m = k.extract_matching();
    m.check_consistent().unwrap();
    check_feasible(&k.arena().q, &m, &k.duals()).unwrap();
    assert_eq!(k.arena().warm_reinits, 1);
    assert!(k.arena().last_init_reused);
}

#[test]
fn miri_ot_masses_conserved_small() {
    let n = 6;
    let costs = random_costs(n, 7);
    let supply: Vec<u64> = (0..n as u64).map(|b| 2 + b % 3).collect();
    let demand: Vec<u64> = (0..n as u64).map(|a| 3 + a % 2).collect();
    assert!(demand.iter().sum::<u64>() >= supply.iter().sum::<u64>());
    let mut k = ScalarKernel::new();
    k.init(&costs, 0.2, Some((&supply[..], &demand[..])));
    k.run_to_termination(100_000).unwrap();
    k.check_invariants().unwrap();
    let flow = k.unit_flow();
    for b in 0..n {
        let shipped: u64 = (0..n).map(|a| flow[b * n + a]).sum();
        assert_eq!(shipped + k.arena().b_free()[b], supply[b], "b={b}");
    }
    assert!(k.arena().max_classes_seen <= 2, "Lemma 4.1");
}

/// The implicit-row-LRU path: scalar implicit solves stream rows through
/// the `RowScratch` cache, and the result must be byte-identical to dense.
#[test]
fn miri_implicit_row_lru_small() {
    let n = 10;
    let dense = random_costs(n, 11);
    let costs = generated_mirror(&dense, n);
    let mut kd = ScalarKernel::new();
    kd.init(&dense, 0.25, None);
    kd.run_to_termination(10_000).unwrap();
    let mut ki = ScalarKernel::new();
    ki.init_src(&costs.source(), 0.25, None);
    ki.run_to_termination(10_000).unwrap();
    ki.check_invariants().unwrap();
    assert_eq!(kd.extract_matching(), ki.extract_matching());
    assert_eq!(kd.duals(), ki.duals());
    assert_eq!(kd.arena().rounds, ki.arena().rounds);
    assert_eq!(ki.arena().cost_state_bytes(), 0, "no resident slab in implicit mode");
}

/// Vector-backend implicit mode builds only the streamed block minima
/// (n = 10 exercises the lane-padding path under Miri).
#[test]
fn miri_implicit_vector_lane_min_small() {
    let n = 10;
    let dense = random_costs(n, 13);
    let costs = generated_mirror(&dense, n);
    let mut kd = VectorKernel::new();
    kd.init(&dense, 0.25, None);
    kd.run_to_termination(10_000).unwrap();
    let mut ki = VectorKernel::new();
    ki.init_src(&costs.source(), 0.25, None);
    ki.run_to_termination(10_000).unwrap();
    ki.check_invariants().unwrap();
    assert_eq!(kd.extract_matching(), ki.extract_matching());
    assert_eq!(kd.duals(), ki.duals());
    assert!(ki.arena().q.is_implicit() && ki.arena().q.cq.is_empty());
}

#[test]
fn miri_quantize_dense_vs_implicit_small() {
    let dense = CostMatrix::from_fn(4, 9, |b, a| ((b * 7 + a * 5) % 11) as f32 / 10.0);
    let costs = Costs::generated(
        GeneratedCosts::new(4, 9, |b, a| ((b * 7 + a * 5) % 11) as f32 / 10.0).unwrap(),
    );
    let qd = QuantizedCosts::new(&dense, 0.15);
    let qi = QuantizedCosts::from_source(&costs.source(), 0.15);
    let mut buf = Vec::new();
    for b in 0..4 {
        assert_eq!(qi.row_units(b, &mut buf), qd.row(b), "row {b}");
        assert_eq!(qi.row_min(b), qd.row_min(b));
    }
    let (mut lane_cq, mut dense_min, mut impl_min) = (Vec::new(), Vec::new(), Vec::new());
    qd.build_lane_blocks(&mut lane_cq, &mut dense_min);
    qi.build_lane_min_implicit(&mut impl_min);
    assert_eq!(impl_min, dense_min);
    let e0 = qi.epoch;
    let mut qi2 = qi.clone();
    qi2.requantize_src(&costs.source(), 0.1);
    assert_ne!(qi2.epoch, e0, "requantize must bump the row-cache epoch");
}

#[test]
fn miri_point_providers_match_dense_small() {
    use otpr::data::synthetic::{euclidean_cost_provider, euclidean_costs, fig1_points};
    let (a, b) = fig1_points(6, 17);
    let dense = euclidean_costs(&b, &a);
    let p = euclidean_cost_provider(&b, &a);
    let costs = Costs::points(p);
    let src = costs.source();
    for bi in 0..6 {
        for ai in 0..6 {
            assert_eq!(src.at(bi, ai), dense.at(bi, ai), "({bi},{ai})");
        }
    }
}

// ---------------------------------------------------------------------
// tsan_* — Chunked/Hybrid-vs-Scalar byte-identity at ≥4 threads
// ---------------------------------------------------------------------

#[test]
fn tsan_chunked_matches_scalar_at_4_and_8_threads() {
    for seed in 0..3u64 {
        let costs = random_costs(24, seed);
        let mut ks = ScalarKernel::new();
        ks.init(&costs, 0.2, None);
        ks.run_to_termination(10_000).unwrap();
        for threads in [4usize, 8] {
            let mut kc = ChunkedKernel::new(threads);
            kc.init(&costs, 0.2, None);
            kc.run_to_termination(10_000).unwrap();
            kc.check_invariants().unwrap();
            assert_eq!(ks.extract_matching(), kc.extract_matching(), "seed {seed} t{threads}");
            assert_eq!(ks.duals(), kc.duals(), "seed {seed} t{threads}");
            assert_eq!(ks.arena().rounds, kc.arena().rounds, "seed {seed} t{threads}");
            assert_eq!(ks.arena().phases, kc.arena().phases, "seed {seed} t{threads}");
        }
    }
}

/// Implicit costs add per-thread `RowScratch` caches to the fan-out; the
/// result contract (and TSan's race check) must hold there too.
#[test]
fn tsan_chunked_implicit_matches_scalar_at_4_threads() {
    let n = 20;
    let dense = random_costs(n, 9);
    let costs = generated_mirror(&dense, n);
    let mut ks = ScalarKernel::new();
    ks.init_src(&costs.source(), 0.2, None);
    ks.run_to_termination(10_000).unwrap();
    let mut kc = ChunkedKernel::new(4);
    kc.init_src(&costs.source(), 0.2, None);
    kc.run_to_termination(10_000).unwrap();
    kc.check_invariants().unwrap();
    assert_eq!(ks.extract_matching(), kc.extract_matching());
    assert_eq!(ks.duals(), kc.duals());
    assert_eq!(ks.arena().rounds, kc.arena().rounds);
}

/// Hybrid backend: the lane-blocked sweep fanned over threads. Same
/// byte-identity contract as chunked, with the shared `lane_min` skip
/// filter as the extra read-only state TSan watches across workers.
#[test]
fn tsan_hybrid_matches_scalar_at_4_and_8_threads() {
    for seed in 0..3u64 {
        let costs = random_costs(24, seed);
        let mut ks = ScalarKernel::new();
        ks.init(&costs, 0.2, None);
        ks.run_to_termination(10_000).unwrap();
        for threads in [4usize, 8] {
            let mut kh = HybridKernel::new(threads);
            kh.init(&costs, 0.2, None);
            kh.run_to_termination(10_000).unwrap();
            kh.check_invariants().unwrap();
            assert_eq!(ks.extract_matching(), kh.extract_matching(), "seed {seed} t{threads}");
            assert_eq!(ks.duals(), kh.duals(), "seed {seed} t{threads}");
            assert_eq!(ks.arena().rounds, kh.arena().rounds, "seed {seed} t{threads}");
            assert_eq!(ks.arena().phases, kh.arena().phases, "seed {seed} t{threads}");
        }
    }
}

/// Hybrid implicit costs: per-thread `RowScratch` LRUs feed the lane
/// sweep, with rows quantized on demand from the provider — the richest
/// shared-state configuration the fan-out has.
#[test]
fn tsan_hybrid_implicit_matches_scalar_at_4_and_8_threads() {
    let n = 20;
    let dense = random_costs(n, 9);
    let costs = generated_mirror(&dense, n);
    let mut ks = ScalarKernel::new();
    ks.init_src(&costs.source(), 0.2, None);
    ks.run_to_termination(10_000).unwrap();
    for threads in [4usize, 8] {
        let mut kh = HybridKernel::new(threads);
        kh.init_src(&costs.source(), 0.2, None);
        kh.run_to_termination(10_000).unwrap();
        kh.check_invariants().unwrap();
        assert_eq!(ks.extract_matching(), kh.extract_matching(), "t{threads}");
        assert_eq!(ks.duals(), kh.duals(), "t{threads}");
        assert_eq!(ks.arena().rounds, kh.arena().rounds, "t{threads}");
    }
}

/// OT masses through the hybrid fan-out: cluster-slot accept state plus
/// the lane skip filter, at 4 and 8 threads.
#[test]
fn tsan_ot_masses_hybrid_matches_scalar() {
    let n = 16;
    let costs = random_costs(n, 21);
    let supply: Vec<u64> = (0..n as u64).map(|b| 2 + b % 4).collect();
    let demand: Vec<u64> = (0..n as u64).map(|a| 4 + a % 3).collect();
    assert!(demand.iter().sum::<u64>() >= supply.iter().sum::<u64>());
    let mut ks = ScalarKernel::new();
    ks.init(&costs, 0.15, Some((&supply[..], &demand[..])));
    ks.run_to_termination(100_000).unwrap();
    for threads in [4usize, 8] {
        let mut kh = HybridKernel::new(threads);
        kh.init(&costs, 0.15, Some((&supply[..], &demand[..])));
        kh.run_to_termination(100_000).unwrap();
        assert_eq!(ks.unit_flow(), kh.unit_flow(), "t{threads}");
        assert_eq!(ks.duals(), kh.duals(), "t{threads}");
    }
}

/// Sparse plan extraction after a threaded OT solve (PR 8): the CSR
/// walk reads the pooled cluster edge lists the fan-out wrote, so TSan
/// verifies the workers' writes are all visible (happens-before the
/// extraction) — and the CSR must agree with both the scalar twin's CSR
/// and the dense `unit_flow` slab entry-for-entry.
#[test]
fn tsan_hybrid_sparse_extraction_matches_scalar() {
    let n = 16;
    let costs = random_costs(n, 21);
    let supply: Vec<u64> = (0..n as u64).map(|b| 2 + b % 4).collect();
    let demand: Vec<u64> = (0..n as u64).map(|a| 4 + a % 3).collect();
    let mut ks = ScalarKernel::new();
    ks.init(&costs, 0.15, Some((&supply[..], &demand[..])));
    ks.run_to_termination(100_000).unwrap();
    let scalar_csr = ks.extract_plan_sparse();
    for threads in [4usize, 8] {
        let mut kh = HybridKernel::new(threads);
        kh.init(&costs, 0.15, Some((&supply[..], &demand[..])));
        kh.run_to_termination(100_000).unwrap();
        let csr = kh.extract_plan_sparse();
        assert_eq!(csr, scalar_csr, "t{threads}");
        // CSR vs the dense slab: same units at the same (b, a) cells
        let flow = kh.unit_flow();
        let mut total = 0u64;
        for b in 0..n {
            for i in csr.row_ptr[b]..csr.row_ptr[b + 1] {
                let a = csr.col_idx[i] as usize;
                assert_eq!(csr.units[i], flow[b * n + a], "t{threads} b={b} a={a}");
                assert!(csr.units[i] > 0, "CSR stores support entries only");
                total += csr.units[i];
            }
        }
        assert_eq!(total, flow.iter().sum::<u64>(), "t{threads}: no cell missed");
    }
}

/// OT masses exercise the cluster-slot accept path under the thread
/// fan-out (Lemma 4.1 slot state is the shared structure TSan watches).
#[test]
fn tsan_ot_masses_chunked_matches_scalar() {
    let n = 16;
    let costs = random_costs(n, 21);
    let supply: Vec<u64> = (0..n as u64).map(|b| 2 + b % 4).collect();
    let demand: Vec<u64> = (0..n as u64).map(|a| 4 + a % 3).collect();
    assert!(demand.iter().sum::<u64>() >= supply.iter().sum::<u64>());
    let mut ks = ScalarKernel::new();
    ks.init(&costs, 0.15, Some((&supply[..], &demand[..])));
    ks.run_to_termination(100_000).unwrap();
    for threads in [4usize, 8] {
        let mut kc = ChunkedKernel::new(threads);
        kc.init(&costs, 0.15, Some((&supply[..], &demand[..])));
        kc.run_to_termination(100_000).unwrap();
        assert_eq!(ks.unit_flow(), kc.unit_flow(), "t{threads}");
        assert_eq!(ks.duals(), kc.duals(), "t{threads}");
    }
}
