//! Property-based suite (util::proptest_mini): the paper's invariants hold
//! after *every* phase on randomized instances, quantization laws hold,
//! and solver outputs always satisfy their structural contracts. Phase
//! state is driven through the shared flow kernel (`core::kernel`) — the
//! one phase loop every push-relabel engine uses.

use otpr::core::duals::{check_feasible, dual_lower_bound_units};
use otpr::core::kernel::{ChunkedKernel, FlowKernel, ScalarKernel, VectorKernel};
use otpr::core::{AssignmentInstance, CostMatrix, QuantizedCosts};
use otpr::data::workloads::Workload;
use otpr::prop_assert;
use otpr::solvers::push_relabel::assignment_phase_cap;
use otpr::util::proptest_mini::{check, check_default, PropConfig};
use otpr::util::rng::Pcg32;

fn random_costs(rng: &mut Pcg32, n: usize) -> CostMatrix {
    CostMatrix::from_fn(n, n, |_, _| rng.next_f32())
}

#[test]
fn prop_feasibility_after_every_phase_sequential() {
    check_default("sequential phase invariants", |rng| {
        let n = 4 + rng.next_below(28) as usize;
        let eps = [0.4, 0.2, 0.1][rng.next_below(3) as usize];
        let costs = random_costs(rng, n);
        let mut k = ScalarKernel::new();
        k.init(&costs, eps, None);
        for _ in 0..500 {
            let out = k.run_phase();
            k.check_invariants().map_err(|e| format!("n={n} eps={eps}: {e}"))?;
            // matching-form invariants: signs, (2)/(3), Lemma 3.2 bound
            check_feasible(&k.arena().q, &k.extract_matching(), &k.duals())
                .map_err(|e| format!("n={n} eps={eps}: {e}"))?;
            if out.terminated {
                return Ok(());
            }
        }
        Err(format!("did not terminate (n={n}, eps={eps})"))
    });
}

#[test]
fn prop_feasibility_after_every_phase_parallel() {
    check_default("parallel phase invariants", |rng| {
        let n = 4 + rng.next_below(24) as usize;
        let eps = [0.4, 0.2][rng.next_below(2) as usize];
        let costs = random_costs(rng, n);
        let threads = 1 + rng.next_below(4) as usize;
        let mut k = ChunkedKernel::new(threads);
        k.init(&costs, eps, None);
        for _ in 0..500 {
            let out = k.run_phase();
            k.check_invariants().map_err(|e| format!("n={n}: {e}"))?;
            check_feasible(&k.arena().q, &k.extract_matching(), &k.duals())
                .map_err(|e| format!("n={n}: {e}"))?;
            if out.terminated {
                return Ok(());
            }
        }
        Err("did not terminate".into())
    });
}

#[test]
fn prop_ot_cluster_invariants() {
    check(
        "ot cluster invariants",
        &PropConfig { cases: 24, ..Default::default() },
        |rng| {
            let n = 4 + rng.next_below(12) as usize;
            let inst = Workload::Fig1 { n }.ot_with_random_masses(rng.next_u64());
            let scaled = otpr::core::ScaledOtInstance::build(&inst, 0.25);
            let mut k = ScalarKernel::new();
            k.init(
                &inst.costs,
                0.25 / 6.0,
                Some((&scaled.supply_units[..], &scaled.demand_units[..])),
            );
            for _ in 0..2000 {
                let out = k.run_phase();
                k.check_invariants()?;
                prop_assert!(
                    k.arena().max_classes_seen <= 2,
                    "Lemma 4.1 violated: {} clusters",
                    k.arena().max_classes_seen
                );
                if out.terminated {
                    return Ok(());
                }
            }
            Err("did not terminate".into())
        },
    );
}

#[test]
fn prop_scalar_chunked_backends_identical() {
    // The kernel contract: every backend produces byte-identical state.
    check(
        "backend equivalence",
        &PropConfig { cases: 16, ..Default::default() },
        |rng| {
            let n = 4 + rng.next_below(20) as usize;
            let eps = [0.4, 0.2, 0.1][rng.next_below(3) as usize];
            let costs = random_costs(rng, n);
            let cap = assignment_phase_cap(eps);
            let mut ks = ScalarKernel::new();
            ks.init(&costs, eps, None);
            ks.run_to_termination(cap)?;
            let threads = 2 + rng.next_below(5) as usize;
            let mut kc = ChunkedKernel::new(threads);
            kc.init(&costs, eps, None);
            kc.run_to_termination(cap)?;
            prop_assert!(
                ks.extract_matching() == kc.extract_matching(),
                "matchings differ (n={n}, eps={eps}, threads={threads})"
            );
            prop_assert!(ks.duals() == kc.duals(), "duals differ");
            prop_assert!(ks.arena().rounds == kc.arena().rounds, "rounds differ");
            Ok(())
        },
    );
}

#[test]
fn prop_vector_backend_identical_to_scalar() {
    // The kernel contract extended to the lane-blocked backend: identical
    // matchings, duals, and round/phase counts on random widths — most of
    // which are not multiples of 8, covering the padding path.
    check(
        "vector backend equivalence",
        &PropConfig { cases: 16, ..Default::default() },
        |rng| {
            let n = 3 + rng.next_below(26) as usize;
            let eps = [0.4, 0.2, 0.1][rng.next_below(3) as usize];
            let costs = random_costs(rng, n);
            let cap = assignment_phase_cap(eps);
            let mut ks = ScalarKernel::new();
            ks.init(&costs, eps, None);
            ks.run_to_termination(cap)?;
            let mut kv = VectorKernel::new();
            kv.init(&costs, eps, None);
            kv.run_to_termination(cap)?;
            kv.check_invariants()?;
            prop_assert!(
                ks.extract_matching() == kv.extract_matching(),
                "matchings differ (n={n}, eps={eps})"
            );
            prop_assert!(ks.duals() == kv.duals(), "duals differ (n={n}, eps={eps})");
            prop_assert!(ks.arena().rounds == kv.arena().rounds, "rounds differ");
            prop_assert!(ks.arena().phases == kv.arena().phases, "phases differ");
            Ok(())
        },
    );
}

#[test]
fn prop_warm_start_invariants_threshold_and_certificates() {
    // The ε-scaling satellite: warm-started solves still satisfy the
    // kernel invariants, meet the final ε's free-unit threshold, and
    // certify with the same gap bound as cold solves.
    use otpr::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default().with_paranoid(true);
    check(
        "warm-start guarantees",
        &PropConfig { cases: 10, ..Default::default() },
        |rng| {
            let n = 6 + rng.next_below(20) as usize;
            let eps = [0.3, 0.15][rng.next_below(2) as usize];
            let costs = random_costs(rng, n);

            // kernel level: schedule 4ε→2ε→ε by hand, checking invariants
            // and the ε-unit free-vertex threshold at every level
            let mut k = VectorKernel::new();
            let schedule = [4.0 * eps / 3.0, 2.0 * eps / 3.0, eps / 3.0];
            k.init(&costs, schedule[0], None);
            for (li, &eps_l) in schedule.iter().enumerate() {
                if li > 0 {
                    k.arena_mut().rescale(&costs, eps_l);
                    k.check_invariants().map_err(|e| format!("post-rescale: {e}"))?;
                }
                k.run_to_termination(assignment_phase_cap(eps_l))?;
                k.check_invariants().map_err(|e| format!("level {li}: {e}"))?;
                prop_assert!(
                    k.arena().free_units() <= k.arena().threshold(),
                    "level {li} missed its ε threshold"
                );
            }
            check_feasible(&k.arena().q, &k.extract_matching(), &k.duals())?;

            // engine level: warm certificate passes with the cold bound
            let problem = Problem::Assignment(AssignmentInstance::new(costs).unwrap());
            let req = SolveRequest::new(eps).certify(true);
            let cold = registry.solve("native-seq", &config, &problem, &req).unwrap();
            let warm = registry.solve("native-vector-warm", &config, &problem, &req).unwrap();
            prop_assert!(warm.stats.warm_started, "warm engine must report warm_started");
            prop_assert!(warm.stats.eps_levels >= 2, "schedule must run ≥ 2 levels");
            let (cc, wc) = (cold.certificate.unwrap(), warm.certificate.unwrap());
            prop_assert!(wc.ok(), "warm certificate failed: {}", wc.summary());
            prop_assert!(
                (wc.bound - cc.bound).abs() < 1e-12,
                "warm gap bound {} != cold bound {}",
                wc.bound,
                cc.bound
            );
            prop_assert!(wc.gap.unwrap() <= wc.bound + 1e-9, "warm gap above bound");
            Ok(())
        },
    );
}

#[test]
fn prop_quantization_laws() {
    check_default("quantization laws", |rng| {
        let n = 2 + rng.next_below(20) as usize;
        let costs = random_costs(rng, n);
        let eps = 0.01 + 0.5 * rng.next_f64();
        let q = QuantizedCosts::new(&costs, eps);
        for b in 0..n {
            for a in 0..n {
                let c = costs.at(b, a) as f64;
                let r = q.rounded(b, a);
                prop_assert!(r <= c + 1e-9, "rounded above original");
                prop_assert!(c - r < q.eps_abs + 1e-9, "error ≥ eps_abs");
                prop_assert!(q.at(b, a) <= q.max_units(), "cq above ⌊1/ε⌋");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dual_certificate_lower_bound() {
    // Lemma 3.1 machinery: after termination, Σy − n (units) never exceeds
    // the rounded optimum; equivalently the matched cost ≤ Σy.
    check_default("dual certificate", |rng| {
        let n = 4 + rng.next_below(24) as usize;
        let costs = random_costs(rng, n);
        let mut k = ScalarKernel::new();
        k.init(&costs, 0.15, None);
        k.run_to_termination(assignment_phase_cap(0.15))?;
        let m = k.extract_matching();
        let y = k.duals();
        let mut matched_units: i64 = 0;
        for (b, &a) in m.match_b.iter().enumerate() {
            if a >= 0 {
                matched_units += k.arena().q.at(b, a as usize) as i64;
            }
        }
        let total_dual: i64 = y.ya.iter().map(|&v| v as i64).sum::<i64>()
            + y.yb.iter().map(|&v| v as i64).sum::<i64>();
        prop_assert!(
            matched_units <= total_dual,
            "matched {matched_units} > Σy {total_dual}"
        );
        let _ = dual_lower_bound_units(&y); // smoke the helper
        Ok(())
    });
}

#[test]
fn prop_matching_completion_always_perfect() {
    check_default("completion perfect", |rng| {
        let n = 1 + rng.next_below(40) as usize;
        let costs = random_costs(rng, n);
        let inst = AssignmentInstance::new(costs).unwrap();
        let eps = 0.05 + 0.4 * rng.next_f64();
        let sol = otpr::solvers::push_relabel::PushRelabel::new()
            .solve_with_param(&inst, eps)
            .map_err(|e| e.to_string())?;
        prop_assert!(sol.matching.is_perfect(), "not perfect (n={n}, eps={eps})");
        sol.matching.check_consistent()?;
        Ok(())
    });
}

#[test]
fn prop_parallel_thread_count_invariance() {
    // Round-snapshot semantics: the result must be identical for any
    // thread count (determinism claim in solvers::parallel_pr).
    check(
        "thread invariance",
        &PropConfig { cases: 16, ..Default::default() },
        |rng| {
            let n = 4 + rng.next_below(24) as usize;
            let costs = random_costs(rng, n);
            let inst = AssignmentInstance::new(costs).unwrap();
            let eps = 0.2;
            let s1 = otpr::solvers::parallel_pr::ParallelPushRelabel::with_threads(1)
                .solve_with_param(&inst, eps)
                .map_err(|e| e.to_string())?;
            let s3 = otpr::solvers::parallel_pr::ParallelPushRelabel::with_threads(3)
                .solve_with_param(&inst, eps)
                .map_err(|e| e.to_string())?;
            prop_assert!(s1.matching == s3.matching, "matchings differ across threads");
            prop_assert!(s1.duals == s3.duals, "duals differ across threads");
            Ok(())
        },
    );
}

#[test]
fn prop_phase_work_bound() {
    // eq. (4): Σ nᵢ ≤ n(1+2ε)/ε
    check_default("phase work bound", |rng| {
        let n = 8 + rng.next_below(40) as usize;
        let eps = [0.3, 0.15, 0.08][rng.next_below(3) as usize];
        let inst = AssignmentInstance::new(random_costs(rng, n)).unwrap();
        let sol = otpr::solvers::push_relabel::PushRelabel::new()
            .solve_with_param(&inst, eps)
            .map_err(|e| e.to_string())?;
        let bound = (n as f64 * (1.0 + 2.0 * eps) / eps).ceil() as u64;
        prop_assert!(
            sol.stats.total_free_processed <= bound,
            "Σnᵢ = {} > {bound}",
            sol.stats.total_free_processed
        );
        Ok(())
    });
}
