//! Serving-layer acceptance tests (PR 10).
//!
//! Three contracts under test, end to end through the public
//! `Coordinator` surface:
//!
//! 1. **Cache hits are byte-identical to fresh solves.** Re-submitting an
//!    identical `(payload, ε, engine, certify)` job returns a `Solution`
//!    whose cost bits, coupling (matching or CSR plan wire bytes), duals,
//!    and certificate all equal the first answer exactly — over the whole
//!    golden corpus (dense assignment *and* OT) and over implicit
//!    point-cloud payloads.
//! 2. **The digest key neither over- nor under-matches.** Different
//!    payloads, ε, engine, or certificate-wish must miss; closure-backed
//!    (`GeneratedCosts`) payloads are undigestable and must never cache.
//! 3. **Admission is total under chaos.** Against ≥ 2 shape-keyed shards
//!    with per-tenant quotas and a seeded fault storm, every `admit()`
//!    resolves to exactly one of Backpressure (observed client-side,
//!    retried) or Accepted-then-one-terminal-outcome — no lost or
//!    double-resolved jobs.

use otpr::api::{Coupling, SolveRequest};
use otpr::coordinator::{
    Admission, Coordinator, CoordinatorConfig, Engine, FaultPlan, JobKind, JobStatus, TenantQuota,
};
use otpr::data::workloads::{golden_corpus, Workload, GOLDEN_SPECS};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn coupling_bytes(c: &Coupling) -> Vec<u8> {
    match c {
        Coupling::Matching(m) => {
            // row-assignment vector is the matching's full identity
            m.match_b.iter().flat_map(|x| x.to_le_bytes()).collect()
        }
        Coupling::Plan(p) => p
            .to_bytes()
            .unwrap_or_else(|| p.as_slice().iter().flat_map(|x| x.to_bits().to_le_bytes()).collect()),
    }
}

/// Submit `kind` twice (sequentially, so the insert from the first solve
/// lands before the second lookup) and assert the replay is bitwise equal.
fn assert_replay_identical(coord: &Coordinator, kind: JobKind, request: SolveRequest, label: &str) {
    let first = coord
        .admit(kind.clone(), request.clone(), Engine::NativeSeq)
        .expect("admit");
    let Admission::Accepted(first) = first else { panic!("{label}: no quota configured") };
    let first = first.wait().expect("first solve resolves");
    assert_eq!(first.status, JobStatus::Served, "{label}: fresh solve serves");
    let fresh = first.result.expect("fresh solve succeeds");

    let again = coord.admit(kind, request, Engine::NativeSeq).expect("admit");
    let Admission::Accepted(again) = again else { panic!("{label}: no quota configured") };
    let again = again.wait().expect("replay resolves");
    assert_eq!(again.status, JobStatus::Served, "{label}: replay serves");
    let cached = again.result.expect("replay succeeds");

    assert_eq!(
        fresh.cost.to_bits(),
        cached.cost.to_bits(),
        "{label}: cost must be bit-identical"
    );
    assert_eq!(
        coupling_bytes(&fresh.coupling),
        coupling_bytes(&cached.coupling),
        "{label}: coupling must be byte-identical"
    );
    assert_eq!(fresh.duals, cached.duals, "{label}: dual certificate must match");
    assert_eq!(fresh.certificate, cached.certificate, "{label}: certificate must match");
    assert!(
        fresh.certificate.as_ref().is_some_and(|c| c.primal_ok),
        "{label}: the certified fresh answer verifies"
    );
}

/// Contract 1, dense: every golden fixture (assignment and OT), solved
/// with a certificate, replays byte-identically out of the cache.
#[test]
fn golden_corpus_cache_hits_are_byte_identical() {
    let cases = golden_corpus().expect("committed golden fixtures load");
    assert_eq!(cases.len(), GOLDEN_SPECS.len(), "corpus is complete");
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, cache_bytes: 8 << 20, ..Default::default() },
        None,
    );
    let mut replayed = 0u64;
    for case in &cases {
        let kind = match (case.assignment(), case.ot()) {
            (Some(inst), _) => JobKind::Assignment(inst),
            (_, Some(inst)) => JobKind::Ot(inst),
            _ => panic!("golden case {} is neither assignment nor OT", case.name),
        };
        assert_replay_identical(&coord, kind, SolveRequest::new(0.25).certify(true), &case.name);
        replayed += 1;
    }
    let metrics = coord.metrics.clone();
    coord.shutdown();
    assert_eq!(
        metrics.cache_hits.load(Ordering::Relaxed),
        replayed,
        "every replay is a hit, none a re-solve"
    );
    assert_eq!(metrics.cache_misses.load(Ordering::Relaxed), replayed, "every first solve misses");
    assert!(metrics.cache_bytes() > 0, "hits come from resident entries");
}

/// Contract 1, implicit: point-cloud payloads (O(n) data, digestable
/// provider) replay byte-identically too — the CSR/matching wire rebuild
/// path, not just the dense clone path.
#[test]
fn implicit_point_cloud_cache_hits_are_byte_identical() {
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, cache_bytes: 4 << 20, ..Default::default() },
        None,
    );
    for (n, seed) in [(24usize, 3u64), (17, 9)] {
        let costs = Workload::Fig1 { n }.implicit_costs(seed).expect("fig1 has an implicit form");
        let kind = JobKind::implicit_assignment(costs).expect("square");
        assert_replay_identical(
            &coord,
            kind,
            SolveRequest::new(0.3).certify(true),
            &format!("implicit n={n} seed={seed}"),
        );
    }
    let metrics = coord.metrics.clone();
    coord.shutdown();
    assert_eq!(metrics.cache_hits.load(Ordering::Relaxed), 2);
}

/// Contract 2: the `(digest, ε, engine, certify)` key must not
/// over-match. Any coordinate changing ⇒ miss; and payloads whose costs
/// are closure-generated have no digest, so they can never produce a hit
/// (stale-answer safety for uncacheable instances).
#[test]
fn digest_key_never_collides_across_payload_eps_engine_or_certify() {
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, cache_bytes: 4 << 20, ..Default::default() },
        None,
    );
    let job = |seed: u64| JobKind::Assignment(Workload::Fig1 { n: 16 }.assignment(seed));
    let wait = |adm: Admission| match adm {
        Admission::Accepted(h) => h.wait().expect("resolves"),
        Admission::Backpressure { .. } => panic!("no quotas configured"),
    };
    // baseline entry
    wait(coord.admit(job(1), SolveRequest::new(0.3), Engine::NativeSeq).expect("admit"));
    let miss_probes = [
        (job(2), SolveRequest::new(0.3), Engine::NativeSeq, "different payload"),
        (job(1), SolveRequest::new(0.2), Engine::NativeSeq, "different eps"),
        (job(1), SolveRequest::new(0.3), Engine::NativeVector, "different engine"),
        (job(1), SolveRequest::new(0.3).certify(true), Engine::NativeSeq, "certificate wish"),
    ];
    let probes = miss_probes.len() as u64;
    for (kind, request, engine, why) in miss_probes {
        let out = wait(coord.admit(kind, request, engine).expect("admit"));
        assert_eq!(out.status, JobStatus::Served, "{why}: probe still serves");
        let hits_now = coord.metrics.cache_hits.load(Ordering::Relaxed);
        assert_eq!(hits_now, 0, "{why} must miss the cache");
    }
    // identical resubmit: the one true hit, proving the misses above were
    // key mismatches rather than a dead cache
    let out = wait(coord.admit(job(1), SolveRequest::new(0.3), Engine::NativeSeq).expect("admit"));
    assert_eq!(out.status, JobStatus::Served);
    assert_eq!(coord.metrics.cache_hits.load(Ordering::Relaxed), 1);

    // closure-generated costs have no digest: byte-identical resubmits
    // still execute fresh every time
    let kind = JobKind::implicit_assignment(GOLDEN_SPECS[0].generated()).expect("square");
    wait(coord.admit(kind.clone(), SolveRequest::new(0.3), Engine::NativeSeq).expect("admit"));
    wait(coord.admit(kind, SolveRequest::new(0.3), Engine::NativeSeq).expect("admit"));
    let metrics = coord.metrics.clone();
    coord.shutdown();
    assert_eq!(
        metrics.cache_hits.load(Ordering::Relaxed),
        1,
        "undigestable payloads must never hit"
    );
    assert_eq!(
        metrics.cache_misses.load(Ordering::Relaxed),
        1 + probes,
        "generated-cost jobs bypass the cache entirely (no recorded miss)"
    );
}

/// Contract 3: the acceptance soak. Two shapes (⇒ two shards), two
/// quota-bound tenants, a seeded storm of panics/transients/delays.
/// Every admit() call terminates in Accepted (possibly after observed,
/// bounded backpressure), and every accepted handle resolves to exactly
/// one terminal outcome.
#[test]
fn admission_soak_every_admit_resolves_to_exactly_one_outcome() {
    let jobs: u64 = std::env::var("OTPR_CHAOS_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(48);
    let plan = FaultPlan::seeded(
        13,
        jobs,
        (jobs / 16).max(2) as usize,
        (jobs / 10).max(3) as usize,
        (jobs / 16).max(2) as usize,
        Duration::from_millis(2),
    );
    let quota = TenantQuota { max_in_flight: 4, max_queue_depth: 4, default_deadline: None };
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            restart_budget: jobs as u32,
            max_retries: jobs as u32,
            default_deadline: Some(Duration::from_secs(60)),
            faults: Some(Arc::new(plan)),
            cache_bytes: 1 << 20,
            tenants: vec![("alpha".into(), quota.clone()), ("beta".into(), quota)],
            ..Default::default()
        },
        None,
    );
    let stall = Instant::now() + Duration::from_secs(120);
    let mut backpressured = 0u64;
    let mut handles = Vec::new();
    for i in 0..jobs {
        // alternating shapes land on two different shards; seeds are all
        // distinct so the cache digests everything but replays nothing
        let (n, tenant) = if i % 2 == 0 { (14, "alpha") } else { (10, "beta") };
        let kind = JobKind::Assignment(Workload::Fig1 { n }.assignment(i));
        let request = SolveRequest::new(0.3).for_tenant(tenant);
        let handle = loop {
            match coord.admit(kind.clone(), request.clone(), Engine::NativeSeq).expect("admit") {
                Admission::Accepted(h) => break h,
                Admission::Backpressure { retry_after } => {
                    backpressured += 1;
                    assert!(retry_after > Duration::ZERO, "the hint must be actionable");
                    assert!(Instant::now() < stall, "admission must not starve under quota");
                    std::thread::sleep(retry_after);
                }
            }
        };
        handles.push(handle);
    }
    let accepted = handles.len() as u64;
    let (mut served, mut degraded, mut shed, mut failed) = (0u64, 0u64, 0u64, 0u64);
    for h in handles {
        let out = h.wait().expect("every accepted handle resolves — no lost replies");
        match out.status {
            JobStatus::Served => served += 1,
            JobStatus::Degraded { .. } => degraded += 1,
            JobStatus::Shed { .. } => shed += 1,
            JobStatus::Failed { .. } => failed += 1,
        }
    }
    assert_eq!(served + degraded + shed + failed, accepted, "taxonomy covers every admission");
    assert_eq!(failed, 0, "generous budgets retry the whole storm into success");
    assert_eq!(shed, 0, "nothing expires under a 60s deadline");
    let metrics = coord.metrics.clone();
    coord.shutdown();
    assert_eq!(metrics.completed.load(Ordering::Relaxed), accepted);
    assert_eq!(metrics.queue_depth(), 0, "the saturation gauge drains to zero");
    assert!(
        metrics.shard_counters().len() >= 2,
        "two shapes must have run on two shape-keyed shards"
    );
    assert_eq!(
        metrics.worker_panics.load(Ordering::Relaxed),
        metrics.worker_restarts.load(Ordering::Relaxed),
        "under budget, every panicked worker is replaced"
    );
    assert_eq!(
        metrics.backpressured_jobs.load(Ordering::Relaxed),
        backpressured,
        "server-side backpressure count matches what the client observed"
    );
    // quota arithmetic is quiet at the end: nothing left in flight
    assert!(backpressured > 0 || jobs < 16, "a 4-deep quota under 48 jobs should backpressure");
}
