//! OT-solver integration: §4 push-relabel OT vs exact SSP and Sinkhorn,
//! over uniform / random / skewed mass profiles and both workload costs.

use otpr::core::{CostMatrix, OtInstance};
use otpr::data::workloads::{random_simplex, Workload};
use otpr::solvers::ot_push_relabel::OtPushRelabel;
use otpr::solvers::sinkhorn::Sinkhorn;
use otpr::solvers::ssp_ot::SspExactOt;
use otpr::solvers::OtSolver;
use otpr::util::rng::Pcg32;

fn skewed_masses(n: usize, seed: u64) -> Vec<f64> {
    // one heavy atom + light tail — stresses the θ-scaling rounding
    let mut rng = Pcg32::new(seed);
    let mut v = random_simplex(n, &mut rng);
    v[0] += 0.5;
    let sum: f64 = v.iter().sum();
    v.iter_mut().for_each(|x| *x /= sum);
    v
}

fn check_instance(inst: &OtInstance, eps: f64) {
    let c_max = inst.costs.max() as f64;
    let exact = SspExactOt::default().solve_ot(inst, 0.0).unwrap();
    let sol = OtPushRelabel::new().solve_ot(inst, eps).unwrap();
    // all supply shipped
    assert!((sol.plan.total_mass() - 1.0).abs() < 1e-9);
    // additive guarantee
    assert!(
        sol.cost <= exact.cost + eps * c_max + 1e-9,
        "pr-ot {} > exact {} + {}",
        sol.cost,
        exact.cost,
        eps * c_max
    );
    // cannot beat the exact optimum by more than mass-rounding slack
    let n = inst.n() as f64;
    let theta = 4.0 * n / eps;
    assert!(sol.cost >= exact.cost - 2.0 * n / theta * c_max - 1e-9);
}

#[test]
fn uniform_masses_fig1_costs() {
    for (n, eps) in [(10, 0.4), (20, 0.25), (30, 0.15)] {
        let inst = OtInstance::uniform(Workload::Fig1 { n }.costs(3)).unwrap();
        check_instance(&inst, eps);
    }
}

#[test]
fn random_masses_fig1_costs() {
    for seed in 0..3 {
        let inst = Workload::Fig1 { n: 16 }.ot_with_random_masses(seed);
        check_instance(&inst, 0.25);
    }
}

#[test]
fn skewed_masses_survive_scaling() {
    let n = 18;
    let costs = Workload::Fig1 { n }.costs(9);
    let inst =
        OtInstance::new(costs, skewed_masses(n, 1), skewed_masses(n, 2)).unwrap();
    check_instance(&inst, 0.2);
}

#[test]
fn image_costs_ot() {
    let inst = Workload::Fig2 { n: 14 }.ot_with_random_masses(4);
    check_instance(&inst, 0.3);
}

#[test]
fn rectangular_ot() {
    // more demand points than supply points
    let mut rng = Pcg32::new(7);
    let costs = CostMatrix::from_fn(8, 14, |_, _| rng.next_f32());
    let demand = random_simplex(14, &mut rng);
    let supply = random_simplex(8, &mut rng);
    let inst = OtInstance::new(costs, demand, supply).unwrap();
    check_instance(&inst, 0.25);
}

#[test]
fn sinkhorn_and_pr_land_in_same_band() {
    // both ε-approximations of the same optimum: they must agree within
    // the sum of their budgets
    let inst = Workload::Fig1 { n: 16 }.ot_with_random_masses(11);
    let eps = 0.2;
    let c_max = inst.costs.max() as f64;
    let pr = OtPushRelabel::new().solve_ot(&inst, eps).unwrap();
    let sk = Sinkhorn::log_domain().solve_ot(&inst, eps).unwrap();
    assert!((pr.cost - sk.cost).abs() <= 2.0 * eps * c_max + 1e-9);
}

#[test]
fn plan_is_reusable_as_warm_information() {
    // the compact plan advertised by the paper: support stays near-linear
    let inst = Workload::Fig1 { n: 24 }.ot_with_random_masses(5);
    let sol = OtPushRelabel::new().solve_ot(&inst, 0.2).unwrap();
    let support = sol.plan.support_size();
    assert!(
        support <= 6 * 24,
        "support {support} far above O(n) — plan is not compact"
    );
    // dual/stat reporting contract
    assert!(sol.stats.notes.iter().any(|n| n.starts_with("max_clusters=")));
}

#[test]
fn tiny_eps_matches_exact_closely() {
    let inst = Workload::Fig1 { n: 10 }.ot_with_random_masses(6);
    let exact = SspExactOt::default().solve_ot(&inst, 0.0).unwrap();
    let sol = OtPushRelabel::new().solve_ot(&inst, 0.02).unwrap();
    let c_max = inst.costs.max() as f64;
    assert!((sol.cost - exact.cost).abs() <= 0.02 * c_max + 1e-9);
}
