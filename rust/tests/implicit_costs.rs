//! Implicit-cost (CostProvider) conformance: dense and provider-backed
//! representations of the same instance must be **byte-identical** through
//! every kernel engine — matchings, plans, duals, costs, phase/round
//! counts — while the implicit path never materializes the O(n²) slab.
//!
//! Covers the PR-5 acceptance gates:
//! * dense-vs-implicit identity on the golden corpus for all kernel
//!   engines including the warm variants;
//! * a property sweep over point clouds (dense `euclidean_costs` vs
//!   `SqEuclideanCosts`) across all backends, with non-multiple-of-8
//!   widths exercising the lane-padding path;
//! * rescale-via-provider invariants;
//! * the n=4096 no-slab solve through `native-vector`, asserted by
//!   `SolveStats::cost_state_bytes`.

use otpr::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
use otpr::core::certify::certify;
use otpr::core::kernel::{FlowKernel, VectorKernel};
use otpr::data::workloads::{Workload, GOLDEN_SPECS};
use otpr::prop_assert;
use otpr::util::proptest_mini::{check, PropConfig};

const KERNEL_ENGINES: [&str; 6] = [
    "native-seq",
    "native-parallel",
    "native-vector",
    "native-hybrid",
    "native-seq-warm",
    "native-vector-warm",
];

fn assert_identical(
    dense: &otpr::api::Solution,
    implicit: &otpr::api::Solution,
    label: &str,
) {
    match (dense.matching(), implicit.matching()) {
        (Some(md), Some(mi)) => assert_eq!(md, mi, "{label}: matchings differ"),
        (None, None) => assert_eq!(
            dense.plan().unwrap().as_slice(),
            implicit.plan().unwrap().as_slice(),
            "{label}: plans differ"
        ),
        _ => panic!("{label}: coupling shapes differ across representations"),
    }
    assert_eq!(dense.duals, implicit.duals, "{label}: duals must be byte-identical");
    assert_eq!(dense.cost, implicit.cost, "{label}: costs must be bit-identical");
    assert_eq!(dense.stats.phases, implicit.stats.phases, "{label}: phase counts differ");
    assert_eq!(dense.stats.rounds, implicit.stats.rounds, "{label}: round counts differ");
}

/// The acceptance sweep: every golden case, dense vs generated-provider,
/// every kernel engine (cold and warm), two ε values — byte-identical.
#[test]
fn golden_corpus_dense_vs_implicit_identical_on_all_kernel_engines() {
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    for spec in GOLDEN_SPECS {
        let costs = spec.costs();
        let (dense_p, implicit_p) = match spec.masses() {
            None => (
                Problem::assignment(costs).unwrap(),
                Problem::implicit_assignment(spec.generated()).unwrap(),
            ),
            Some((supply, demand)) => (
                Problem::ot(costs, demand.clone(), supply.clone()).unwrap(),
                Problem::implicit_ot(spec.generated(), demand, supply).unwrap(),
            ),
        };
        assert_eq!(dense_p.kind(), implicit_p.kind(), "{}", spec.name);
        for engine in KERNEL_ENGINES {
            for eps in [0.3, 0.1] {
                let req = SolveRequest::new(eps);
                let d = registry.solve(engine, &config, &dense_p, &req).unwrap();
                let i = registry.solve(engine, &config, &implicit_p, &req).unwrap();
                assert_identical(&d, &i, &format!("{} × {engine} eps={eps}", spec.name));
                assert!(
                    i.stats.cost_state_bytes <= d.stats.cost_state_bytes,
                    "{} × {engine}: implicit holds more cost state than dense",
                    spec.name
                );
                // implicit solutions certify through streamed rows
                let cert = certify(&implicit_p, &i, &req);
                assert!(cert.ok(), "{} × {engine}: {}", spec.name, cert.summary());
                if i.duals.is_some() {
                    assert_eq!(cert.dual_ok, Some(true), "{} × {engine}", spec.name);
                }
            }
        }
    }
}

/// Satellite property test: dense Euclidean costs and the
/// `SqEuclideanCosts` provider built from the same point cloud are
/// byte-identical across all kernel backends; random widths cover the
/// non-multiple-of-8 lane-padding path.
#[test]
fn prop_point_cloud_dense_vs_provider_identical() {
    let registry = SolverRegistry::with_defaults();
    check(
        "point-cloud provider equivalence",
        &PropConfig { cases: 8, ..Default::default() },
        |rng| {
            let n = 5 + rng.next_below(24) as usize;
            let seed = rng.next_u64();
            let eps = [0.3, 0.15][rng.next_below(2) as usize];
            let w = Workload::Fig1 { n };
            let dense_p = Problem::Assignment(w.assignment(seed));
            let implicit_p =
                Problem::implicit_assignment(w.implicit_costs(seed).expect("fig1 implicit"))
                    .expect("square");
            let req = SolveRequest::new(eps);
            for engine in KERNEL_ENGINES {
                let config = SolverConfig::default().with_threads(1 + (seed % 4) as usize);
                let d = registry.solve(engine, &config, &dense_p, &req).map_err(|e| e.to_string())?;
                let i = registry
                    .solve(engine, &config, &implicit_p, &req)
                    .map_err(|e| e.to_string())?;
                prop_assert!(
                    d.matching() == i.matching(),
                    "matchings differ (n={n}, seed={seed}, {engine})"
                );
                prop_assert!(d.duals == i.duals, "duals differ (n={n}, seed={seed}, {engine})");
                prop_assert!(d.cost == i.cost, "costs differ (n={n}, seed={seed}, {engine})");
                prop_assert!(
                    d.stats.rounds == i.stats.rounds,
                    "rounds differ (n={n}, seed={seed}, {engine})"
                );
            }
            Ok(())
        },
    );
}

/// Satellite: rescale-via-provider keeps every invariant — after each
/// in-place ε re-target the implicit arena is ε-feasible, reaches the
/// finer threshold, and matches the dense arena driven through the same
/// schedule.
#[test]
fn prop_rescale_via_provider_invariants() {
    use otpr::core::duals::check_feasible;
    check(
        "implicit rescale invariants",
        &PropConfig { cases: 8, ..Default::default() },
        |rng| {
            let n = 6 + rng.next_below(18) as usize;
            let seed = rng.next_u64();
            let w = Workload::Fig1 { n };
            let dense = w.costs(seed);
            let costs = w.implicit_costs(seed).expect("fig1 implicit");
            let schedule = [0.4, 0.2, 0.1];
            let mut ki = VectorKernel::new();
            ki.init_src(&costs.source(), schedule[0], None);
            let mut kd = VectorKernel::new();
            kd.init(&dense, schedule[0], None);
            for (li, &eps_l) in schedule.iter().enumerate() {
                if li > 0 {
                    ki.arena_mut().rescale_src(&costs.source(), eps_l);
                    kd.arena_mut().rescale(&dense, eps_l);
                    ki.check_invariants().map_err(|e| format!("post-rescale: {e}"))?;
                }
                ki.run_to_termination(100_000)?;
                kd.run_to_termination(100_000)?;
                ki.check_invariants().map_err(|e| format!("level {li}: {e}"))?;
                prop_assert!(
                    ki.arena().free_units() <= ki.arena().threshold(),
                    "level {li} missed its ε threshold (n={n}, seed={seed})"
                );
                prop_assert!(
                    ki.duals() == kd.duals(),
                    "level {li}: implicit duals diverge from dense (n={n}, seed={seed})"
                );
                prop_assert!(
                    ki.arena().q.cq.is_empty(),
                    "rescale materialized a slab (n={n}, seed={seed})"
                );
            }
            check_feasible(&ki.arena().q, &ki.extract_matching(), &ki.duals())?;
            prop_assert!(ki.arena().rescales == 2, "both rescales must run");
            Ok(())
        },
    );
}

/// The no-slab acceptance gate: an n=4096 point-cloud assignment solves
/// through `native-vector` while the kernel's resident cost state stays
/// far below the dense n² f32 slab (the block-min cache is n²/8 i32s).
#[test]
fn n4096_point_cloud_solves_without_dense_slab() {
    let n = 4096usize;
    let costs = Workload::Fig1 { n }.implicit_costs(42).expect("fig1 implicit");
    assert!(costs.source().is_implicit());
    let problem = Problem::implicit_assignment(costs).unwrap();
    let registry = SolverRegistry::with_defaults();
    let sol = registry
        .solve(
            "native-vector",
            &SolverConfig::default(),
            &problem,
            // raw algorithm ε (the paper's parameterization) keeps the
            // phase count small enough for a CI-friendly runtime
            &SolveRequest::new(0.3).raw_eps(),
        )
        .expect("implicit n=4096 solve");
    assert!(sol.matching().unwrap().is_perfect());
    let dense_slab = (n * n * 4) as u64;
    assert!(sol.stats.cost_state_bytes > 0, "kernel engines report their cost state");
    assert!(
        sol.stats.cost_state_bytes < dense_slab / 4,
        "no-slab violated: {} bytes resident vs {} for the dense f32 slab",
        sol.stats.cost_state_bytes,
        dense_slab
    );
    // exactly the block-min cache: nb × na_padded/8 i32s
    assert_eq!(sol.stats.cost_state_bytes, (n * (n / 8) * 4) as u64);
}

/// Implicit jobs flow through the coordinator end-to-end with O(n)
/// payloads: Auto routes them to the no-slab vector backend.
#[test]
fn coordinator_serves_implicit_jobs_via_auto() {
    use otpr::coordinator::{Coordinator, CoordinatorConfig, Engine, JobKind};
    let coord = Coordinator::start(CoordinatorConfig::default(), None);
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let costs = Workload::Fig1 { n: 24 }.implicit_costs(i).expect("fig1 implicit");
            let kind = JobKind::implicit_assignment(costs).unwrap();
            coord.submit(kind, 0.3, Engine::Auto).unwrap()
        })
        .collect();
    for h in handles {
        let out = h.wait().unwrap();
        assert_eq!(out.engine_used, "native-vector", "Auto routes implicit to the no-slab path");
        let sol = out.result.unwrap();
        assert!(sol.matching().unwrap().is_perfect());
        assert!(sol.stats.cost_state_bytes < (24 * 24 * 4) as u64);
    }
    coord.shutdown();
}

/// Engines that genuinely need a dense slab refuse implicit problems with
/// a diagnosable error instead of silently materializing.
#[test]
fn slab_engines_reject_implicit_problems_cleanly() {
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let problem = Problem::implicit_assignment(
        Workload::Fig1 { n: 8 }.implicit_costs(1).expect("fig1 implicit"),
    )
    .unwrap();
    for engine in ["hungarian", "greedy", "lmr"] {
        let err = registry.solve(engine, &config, &problem, &SolveRequest::new(0.1)).unwrap_err();
        assert!(
            err.to_string().contains("requires dense costs"),
            "{engine}: unexpected error {err}"
        );
    }
    let err = registry
        .solve("sinkhorn-native", &config, &problem, &SolveRequest::new(0.2))
        .unwrap_err();
    assert!(err.to_string().contains("implicit"), "sinkhorn error must name the cause: {err}");
    // ...and the deliberate escape hatch works
    let dense = problem.to_dense().unwrap();
    let sol = registry.solve("hungarian", &config, &dense, &SolveRequest::new(0.0)).unwrap();
    assert!(sol.matching().unwrap().is_perfect());
}

/// Warm engines early-stop redundant intermediate levels and still hold
/// the dense-vs-implicit identity (both paths share the driver policy).
#[test]
fn warm_early_stop_identical_dense_vs_implicit() {
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let w = Workload::Fig1 { n: 20 };
    let dense_p = Problem::Assignment(w.assignment(9));
    let implicit_p =
        Problem::implicit_assignment(w.implicit_costs(9).expect("fig1 implicit")).unwrap();
    let req = SolveRequest::new(0.25);
    let d = registry.solve("native-vector-warm", &config, &dense_p, &req).unwrap();
    let i = registry.solve("native-vector-warm", &config, &implicit_p, &req).unwrap();
    assert_identical(&d, &i, "warm early-stop");
    assert_eq!(d.stats.eps_levels, i.stats.eps_levels, "identical level schedules");
    assert_eq!(d.stats.notes, i.stats.notes, "identical skip records");
}
