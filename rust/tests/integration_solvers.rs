//! Cross-solver integration: every assignment solver against the exact
//! Hungarian oracle on every workload family, plus solver-vs-solver
//! consistency and reporting contracts.

use otpr::data::workloads::Workload;
use otpr::solvers::greedy::GreedyMatcher;
use otpr::solvers::hungarian::Hungarian;
use otpr::solvers::parallel_pr::ParallelPushRelabel;
use otpr::solvers::push_relabel::PushRelabel;
use otpr::solvers::{AssignmentSolver, SolveStats};

fn workloads(n: usize) -> Vec<Workload> {
    vec![
        Workload::Fig1 { n },
        Workload::Fig2 { n },
        Workload::RandomCosts { n },
        Workload::Clustered { n, k: 4, sigma: 0.08 },
    ]
}

#[test]
fn additive_guarantee_all_workloads() {
    let n = 60;
    let eps = 0.1;
    for wl in workloads(n) {
        for seed in [1u64, 99] {
            let inst = wl.assignment(seed);
            let c_max = inst.costs.max() as f64;
            let exact = Hungarian.solve_assignment(&inst, 0.0).unwrap();
            for solver in
                [&PushRelabel::new() as &dyn AssignmentSolver, &ParallelPushRelabel::with_threads(3)]
            {
                let sol = solver.solve_assignment(&inst, eps).unwrap();
                assert!(sol.matching.is_perfect(), "{} on {}", solver.name(), wl.name());
                let budget = eps * n as f64 * c_max; // trait contract: ε overall
                assert!(
                    sol.cost <= exact.cost + budget + 1e-6,
                    "{} on {} seed {seed}: {} > {} + {budget}",
                    solver.name(),
                    wl.name(),
                    sol.cost,
                    exact.cost
                );
            }
        }
    }
}

#[test]
fn eps_sweep_budget_respected() {
    let inst = Workload::Fig1 { n: 80 }.assignment(5);
    let exact = Hungarian.solve_assignment(&inst, 0.0).unwrap();
    let c_max = inst.costs.max() as f64;
    for eps in [0.5, 0.25, 0.1, 0.05, 0.02] {
        let sol = PushRelabel::new().solve_assignment(&inst, eps).unwrap();
        assert!(sol.cost <= exact.cost + eps * 80.0 * c_max + 1e-6, "eps={eps}");
    }
}

#[test]
fn fine_eps_approaches_exact() {
    for seed in 0..3 {
        let inst = Workload::RandomCosts { n: 20 }.assignment(seed);
        let h = Hungarian.solve_assignment(&inst, 0.0).unwrap();
        let pr = PushRelabel::new().solve_with_param(&inst, 0.002).unwrap();
        assert!(pr.cost >= h.cost - 1e-9, "cannot beat exact");
        assert!(pr.cost <= h.cost + 3.0 * 0.002 * 20.0 + 1e-9);
    }
}

#[test]
fn greedy_is_dominated_by_exact_but_valid() {
    let inst = Workload::Fig2 { n: 30 }.assignment(2);
    let g = GreedyMatcher.solve_assignment(&inst, 0.0).unwrap();
    let h = Hungarian.solve_assignment(&inst, 0.0).unwrap();
    assert!(g.matching.is_perfect());
    assert!(g.cost >= h.cost - 1e-9);
}

#[test]
fn stats_are_populated() {
    let inst = Workload::Fig1 { n: 100 }.assignment(7);
    let sol = PushRelabel::new().solve_assignment(&inst, 0.2).unwrap();
    let SolveStats { phases, total_free_processed, seconds, .. } = sol.stats;
    assert!(phases > 0);
    assert!(total_free_processed >= 100);
    assert!(seconds > 0.0);
    let par = ParallelPushRelabel::with_threads(2).solve_assignment(&inst, 0.2).unwrap();
    assert!(par.stats.rounds >= par.stats.phases, "each phase needs ≥1 round");
}

#[test]
fn sequential_and_parallel_same_guarantees_different_paths() {
    let inst = Workload::Clustered { n: 50, k: 3, sigma: 0.02 }.assignment(3);
    let exact = Hungarian.solve_assignment(&inst, 0.0).unwrap();
    let c_max = inst.costs.max() as f64;
    let eps = 0.15;
    let s = PushRelabel::new().solve_assignment(&inst, eps).unwrap();
    let p = ParallelPushRelabel::with_threads(4).solve_assignment(&inst, eps).unwrap();
    for sol in [&s, &p] {
        assert!(sol.cost <= exact.cost + eps * 50.0 * c_max + 1e-6);
    }
}

#[test]
fn degenerate_zero_cost_instance() {
    let costs = otpr::core::CostMatrix::zeros(16, 16);
    let inst = otpr::core::AssignmentInstance::new(costs).unwrap();
    let sol = PushRelabel::new().solve_assignment(&inst, 0.1).unwrap();
    assert!(sol.matching.is_perfect());
    assert_eq!(sol.cost, 0.0);
}

// ---------------------------------------------------------------------------
// §3.3 unbalanced case (|B| < |A|): the main routine produces an ε-feasible
// matching of size ≥ (1−ε)|B| within ε|B| of the optimal (Lemma 3.5).
// ---------------------------------------------------------------------------

mod unbalanced {
    use otpr::core::duals::check_feasible;
    use otpr::core::kernel::{FlowKernel, ScalarKernel};
    use otpr::core::matching::FREE;
    use otpr::core::CostMatrix;
    use otpr::solvers::hungarian;
    use otpr::solvers::push_relabel::assignment_phase_cap;
    use otpr::util::rng::Pcg32;

    fn rect_costs(nb: usize, na: usize, seed: u64) -> CostMatrix {
        let mut rng = Pcg32::new(seed);
        CostMatrix::from_fn(nb, na, |_, _| rng.next_f32())
    }

    #[test]
    fn lemma_3_5_additive_bound() {
        for seed in 0..3 {
            let (nb, na) = (20usize, 35usize);
            let costs = rect_costs(nb, na, seed);
            let (_, opt, _, _) = hungarian::solve_exact(&costs).unwrap();
            let eps = 0.1;
            let mut k = ScalarKernel::new();
            k.init(&costs, eps, None);
            k.run_to_termination(assignment_phase_cap(eps)).unwrap();
            k.check_invariants().unwrap();
            let mut m = k.extract_matching();
            check_feasible(&k.arena().q, &m, &k.duals()).unwrap();
            // cardinality ≥ (1 − ε)|B|
            let size = m.size();
            assert!(
                size as f64 >= (1.0 - eps) * nb as f64,
                "matching size {size} < (1-ε)|B|"
            );
            // complete and compare: error ≤ ε|B| in rounded units plus the
            // rounding (ε|B|) and completion (ε|B|) terms → 3ε|B|·c_max.
            m.complete_arbitrarily();
            assert_eq!(m.size(), nb);
            let cost = m.cost(&costs);
            let budget = 3.0 * eps * nb as f64 * costs.max() as f64;
            assert!(
                cost <= opt + budget + 1e-6,
                "seed {seed}: {cost} > {opt} + {budget}"
            );
        }
    }

    #[test]
    fn invariants_hold_every_phase_unbalanced() {
        let costs = rect_costs(12, 30, 9);
        let mut k = ScalarKernel::new();
        k.init(&costs, 0.2, None);
        for _ in 0..200 {
            let out = k.run_phase();
            k.check_invariants().unwrap();
            check_feasible(&k.arena().q, &k.extract_matching(), &k.duals()).unwrap();
            if out.terminated {
                break;
            }
        }
        // every matched edge references a valid A vertex
        for &a in &k.extract_matching().match_b {
            assert!(a == FREE || (a as usize) < 30);
        }
    }

    #[test]
    fn all_b_matchable_when_na_much_larger() {
        let costs = rect_costs(8, 64, 3);
        let mut k = ScalarKernel::new();
        k.init(&costs, 0.05, None);
        k.run_to_termination(assignment_phase_cap(0.05)).unwrap();
        let mut m = k.extract_matching();
        m.complete_arbitrarily();
        assert_eq!(m.size(), 8);
        assert!(m.check_consistent().is_ok());
    }
}
