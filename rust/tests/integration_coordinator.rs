//! Coordinator end-to-end: mixed job streams, backpressure, failure
//! isolation, metrics accounting, and (when artifacts exist) the XLA
//! engine behind the service.

use otpr::coordinator::{Coordinator, CoordinatorConfig, Engine, JobKind, JobResult};
use otpr::data::workloads::Workload;
use otpr::runtime::XlaRuntime;
use std::sync::Arc;

fn assignment(n: usize, seed: u64) -> JobKind {
    JobKind::Assignment(Workload::Fig1 { n }.assignment(seed))
}

fn ot(n: usize, seed: u64) -> JobKind {
    JobKind::Ot(Workload::Fig1 { n }.ot_with_random_masses(seed))
}

#[test]
fn mixed_stream_completes() {
    let coord = Coordinator::start(CoordinatorConfig { workers: 3, ..Default::default() }, None);
    let mut handles = Vec::new();
    for i in 0..10 {
        handles.push(coord.submit(assignment(24, i), 0.3, Engine::NativeSeq).unwrap());
        if i % 3 == 0 {
            handles.push(coord.submit(ot(10, i), 0.3, Engine::Auto).unwrap());
        }
    }
    let total = handles.len();
    let mut assignments = 0;
    let mut ots = 0;
    for h in handles {
        match h.wait().unwrap().result.unwrap() {
            JobResult::Assignment(s) => {
                assert!(s.matching.is_perfect());
                assignments += 1;
            }
            JobResult::Ot(s) => {
                assert!((s.plan.total_mass() - 1.0).abs() < 1e-9);
                ots += 1;
            }
        }
    }
    assert_eq!(assignments + ots, total);
    assert_eq!(ots, 4);
    let snap = coord.metrics.snapshot();
    assert!(snap.contains(&format!("completed={total}")), "{snap}");
    coord.shutdown();
}

#[test]
fn backpressure_small_queue() {
    // queue of 1 forces submit() to block rather than drop jobs
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 1, queue_capacity: 1, ..Default::default() },
        None,
    );
    let handles: Vec<_> =
        (0..8).map(|i| coord.submit(assignment(16, i), 0.4, Engine::NativeSeq).unwrap()).collect();
    for h in handles {
        assert!(h.wait().unwrap().result.is_ok());
    }
    coord.shutdown();
}

#[test]
fn worker_failure_isolated() {
    let coord = Coordinator::start(CoordinatorConfig::default(), None);
    // Xla without runtime fails; neighbours succeed
    let bad = coord.submit(assignment(16, 0), 0.3, Engine::Xla).unwrap();
    let good = coord.submit(assignment(16, 1), 0.3, Engine::NativeSeq).unwrap();
    assert!(bad.wait().unwrap().result.is_err());
    assert!(good.wait().unwrap().result.is_ok());
    let snap = coord.metrics.snapshot();
    assert!(snap.contains("failed=1"), "{snap}");
    coord.shutdown();
}

#[test]
fn batching_is_recorded() {
    let coord = Coordinator::start(CoordinatorConfig { workers: 2, ..Default::default() }, None);
    let handles: Vec<_> = (0..12)
        .map(|i| coord.submit(assignment(12, i), 0.4, Engine::NativeSeq).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    let snap = coord.metrics.snapshot();
    assert!(snap.contains("batches:"), "{snap}");
    coord.shutdown();
}

#[test]
fn sinkhorn_engine_on_assignment_jobs() {
    let coord = Coordinator::start(CoordinatorConfig::default(), None);
    let h = coord.submit(assignment(16, 3), 0.25, Engine::SinkhornNative).unwrap();
    match h.wait().unwrap().result.unwrap() {
        JobResult::Ot(sol) => assert!(sol.cost > 0.0),
        _ => panic!("sinkhorn returns a transport plan"),
    }
    coord.shutdown();
}

#[test]
fn xla_engine_through_coordinator_when_artifacts_exist() {
    let Ok(runtime) = XlaRuntime::open_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, ..Default::default() },
        Some(Arc::clone(&runtime)),
    );
    // two same-bucket jobs exercise the compile cache through batching
    let h1 = coord.submit(assignment(256, 1), 0.3, Engine::Xla).unwrap();
    let h2 = coord.submit(assignment(256, 2), 0.3, Engine::Xla).unwrap();
    for h in [h1, h2] {
        let out = h.wait().unwrap();
        let res = out.result.expect("xla job should succeed");
        match res {
            JobResult::Assignment(sol) => {
                assert!(sol.matching.is_perfect());
                assert!(sol.stats.notes.iter().any(|n| n == "bucket=256"));
            }
            _ => panic!("expected assignment result"),
        }
        assert_eq!(out.engine_used, "xla");
    }
    coord.shutdown();
}

#[test]
fn auto_routes_large_to_xla_when_available() {
    let Ok(runtime) = XlaRuntime::open_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let coord = Coordinator::start(CoordinatorConfig::default(), Some(runtime));
    let h = coord.submit(assignment(512, 1), 0.4, Engine::Auto).unwrap();
    let out = h.wait().unwrap();
    assert_eq!(out.engine_used, "xla");
    assert!(out.result.is_ok());
    coord.shutdown();
}
