//! Coordinator end-to-end: mixed job streams, backpressure, failure
//! isolation, metrics accounting, per-job wall-clock budgets with live
//! progress, and (when artifacts exist) the XLA engine behind the service.

use otpr::api::{CancelToken, SolveRequest};
use otpr::coordinator::{Coordinator, CoordinatorConfig, Engine, JobKind};
use otpr::data::workloads::Workload;
use otpr::runtime::XlaRuntime;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn assignment(n: usize, seed: u64) -> JobKind {
    JobKind::Assignment(Workload::Fig1 { n }.assignment(seed))
}

fn ot(n: usize, seed: u64) -> JobKind {
    JobKind::Ot(Workload::Fig1 { n }.ot_with_random_masses(seed))
}

#[test]
fn mixed_stream_completes() {
    let coord = Coordinator::start(CoordinatorConfig { workers: 3, ..Default::default() }, None);
    let mut handles = Vec::new();
    for i in 0..10 {
        handles.push(coord.submit(assignment(24, i), 0.3, Engine::NativeSeq).unwrap());
        if i % 3 == 0 {
            handles.push(coord.submit(ot(10, i), 0.3, Engine::Auto).unwrap());
        }
    }
    let total = handles.len();
    let mut assignments = 0;
    let mut ots = 0;
    for h in handles {
        let sol = h.wait().unwrap().result.unwrap();
        if let Some(m) = sol.matching() {
            assert!(m.is_perfect());
            assignments += 1;
        } else {
            let p = sol.plan().expect("a solution is a matching or a plan");
            assert!((p.total_mass() - 1.0).abs() < 1e-9);
            ots += 1;
        }
    }
    assert_eq!(assignments + ots, total);
    assert_eq!(ots, 4);
    let snap = coord.metrics.snapshot();
    assert!(snap.contains(&format!("completed={total}")), "{snap}");
    coord.shutdown();
}

#[test]
fn backpressure_small_queue() {
    // queue of 1 forces submit() to block rather than drop jobs
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 1, queue_capacity: 1, ..Default::default() },
        None,
    );
    let handles: Vec<_> =
        (0..8).map(|i| coord.submit(assignment(16, i), 0.4, Engine::NativeSeq).unwrap()).collect();
    for h in handles {
        assert!(h.wait().unwrap().result.is_ok());
    }
    coord.shutdown();
}

#[test]
fn worker_failure_isolated() {
    let coord = Coordinator::start(CoordinatorConfig::default(), None);
    // Xla without runtime fails; neighbours succeed
    let bad = coord.submit(assignment(16, 0), 0.3, Engine::Xla).unwrap();
    let good = coord.submit(assignment(16, 1), 0.3, Engine::NativeSeq).unwrap();
    assert!(bad.wait().unwrap().result.is_err());
    assert!(good.wait().unwrap().result.is_ok());
    let snap = coord.metrics.snapshot();
    assert!(snap.contains("failed=1"), "{snap}");
    coord.shutdown();
}

#[test]
fn batching_is_recorded() {
    let coord = Coordinator::start(CoordinatorConfig { workers: 2, ..Default::default() }, None);
    let handles: Vec<_> = (0..12)
        .map(|i| coord.submit(assignment(12, i), 0.4, Engine::NativeSeq).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    let snap = coord.metrics.snapshot();
    assert!(snap.contains("batches:"), "{snap}");
    coord.shutdown();
}

#[test]
fn sinkhorn_engine_on_assignment_jobs() {
    let coord = Coordinator::start(CoordinatorConfig::default(), None);
    let h = coord.submit(assignment(16, 3), 0.25, Engine::SinkhornNative).unwrap();
    let sol = h.wait().unwrap().result.unwrap();
    assert!(sol.plan().is_some(), "sinkhorn returns a transport plan");
    assert!(sol.cost > 0.0);
    coord.shutdown();
}

#[test]
fn baseline_engines_through_coordinator() {
    let coord = Coordinator::start(CoordinatorConfig::default(), None);
    let approx = coord.submit(assignment(20, 5), 0.2, Engine::NativeSeq).unwrap();
    let exact = coord.submit(assignment(20, 5), 0.0, Engine::Hungarian).unwrap();
    let a = approx.wait().unwrap().result.unwrap();
    let e = exact.wait().unwrap().result.unwrap();
    assert!(a.cost >= e.cost - 1e-9, "exact is a lower bound");
    coord.shutdown();
}

#[test]
fn wall_clock_budget_cancels_with_progress_reported() {
    // The acceptance scenario: drive the coordinator with a per-job
    // wall-clock budget and observe (a) the budgeted job stops early and
    // says so, (b) progress streams through the observer on a normal job,
    // and (c) the metrics layer saw the phase events.
    let coord = Coordinator::start(CoordinatorConfig { workers: 2, ..Default::default() }, None);

    // (a) zero budget: returns within one phase, notes "cancelled"
    let rushed = SolveRequest::new(0.01).with_budget(Duration::ZERO);
    let h = coord.submit_request(assignment(200, 1), rushed, Engine::NativeSeq).unwrap();
    let sol = h.wait().unwrap().result.expect("budgeted job still returns a solution");
    assert!(sol.is_cancelled(), "notes: {:?}", sol.stats.notes);
    assert!(sol.stats.phases <= 1, "must stop within one phase, ran {}", sol.stats.phases);
    assert!(sol.matching().unwrap().is_perfect(), "arbitrary completion still applies");

    // (b) generous budget + observer: completes normally, events observed
    let events = Arc::new(AtomicUsize::new(0));
    let counter = events.clone();
    let watched = SolveRequest::new(0.2)
        .with_budget(Duration::from_secs(60))
        .with_observer(move |p| {
            assert!(p.phase >= 1);
            counter.fetch_add(1, Ordering::Relaxed);
        });
    let h = coord.submit_request(assignment(64, 2), watched, Engine::NativeSeq).unwrap();
    let sol = h.wait().unwrap().result.unwrap();
    assert!(!sol.is_cancelled());
    assert!(sol.stats.phases > 0);
    assert!(
        events.load(Ordering::Relaxed) >= sol.stats.phases.saturating_sub(1),
        "observer saw {} events for {} phases",
        events.load(Ordering::Relaxed),
        sol.stats.phases
    );

    // (c) the coordinator teed the same progress into per-engine metrics
    let counters = coord.metrics.engine_counters();
    let seq = counters.iter().find(|c| c.engine == "native-seq").expect("engine counted");
    assert!(seq.phases > 0, "phase events must reach metrics");
    assert_eq!(seq.jobs, 2);
    coord.shutdown();
}

#[test]
fn caller_cancellation_token_respected() {
    let coord = Coordinator::start(CoordinatorConfig::default(), None);
    let token = CancelToken::new();
    token.cancel(); // cancel before the job is even picked up
    let req = SolveRequest::new(0.05).with_cancel(token);
    let h = coord.submit_request(assignment(150, 3), req, Engine::NativeParallel).unwrap();
    let sol = h.wait().unwrap().result.unwrap();
    assert!(sol.is_cancelled());
    assert_eq!(sol.stats.phases, 0);
    coord.shutdown();
}

fn implicit(n: usize, seed: u64) -> JobKind {
    JobKind::implicit_assignment(Workload::Fig1 { n }.implicit_costs(seed).expect("fig1 implicit"))
        .expect("implicit problem")
}

/// Every branch of the shared Auto table (`auto_kernel_engine`) observed
/// end-to-end through `engine_used`, at 4 solver threads: the small dense
/// fast path, the small implicit route, and both large routes fan to the
/// hybrid backend. The resolved engines also show up in the
/// `auto_routed_jobs` metric.
#[test]
fn auto_routing_pins_each_branch_at_4_threads() {
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, solver_threads: 4, ..Default::default() },
        None,
    );
    let cases: [(JobKind, f64, &str); 4] = [
        (assignment(16, 1), 0.3, "native-seq"),
        (implicit(16, 2), 0.3, "native-vector"),
        (assignment(600, 3), 0.4, "native-hybrid"),
        (implicit(600, 4), 0.4, "native-hybrid"),
    ];
    for (kind, eps, expect) in cases {
        let h = coord.submit(kind, eps, Engine::Auto).unwrap();
        let out = h.wait().unwrap();
        assert_eq!(out.engine_used, expect);
        assert!(out.result.is_ok(), "{expect} job failed");
    }
    let counters = coord.metrics.engine_counters();
    let routed: u64 = counters.iter().map(|c| c.auto_routed).sum();
    assert_eq!(routed, 4, "every Auto job is counted against its resolved engine");
    let hybrid = counters.iter().find(|c| c.engine == "native-hybrid").unwrap();
    assert_eq!(hybrid.auto_routed, 2);
    coord.shutdown();
}

/// The `threads == 1` degenerate case must resolve to a sequential
/// engine — never hybrid (a single-thread fan-out is pure overhead).
#[test]
fn auto_routing_single_thread_never_hybrid() {
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 1, solver_threads: 1, ..Default::default() },
        None,
    );
    for (kind, expect) in [
        (assignment(16, 1), "native-seq"),
        (assignment(600, 2), "native-vector"),
        (implicit(600, 3), "native-vector"),
    ] {
        let h = coord.submit(kind, 0.4, Engine::Auto).unwrap();
        let out = h.wait().unwrap();
        assert_eq!(out.engine_used, expect);
        assert!(out.result.is_ok());
    }
    coord.shutdown();
}

/// Explicitly requested hybrid jobs run end-to-end through the service
/// (dense and implicit) and report the fan-out width.
#[test]
fn hybrid_engine_through_coordinator() {
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, solver_threads: 4, ..Default::default() },
        None,
    );
    let hd = coord.submit(assignment(24, 5), 0.3, Engine::NativeHybrid).unwrap();
    let hi = coord.submit(implicit(24, 5), 0.3, Engine::NativeHybrid).unwrap();
    let sd = hd.wait().unwrap().result.unwrap();
    let si = hi.wait().unwrap().result.unwrap();
    assert!(sd.matching().unwrap().is_perfect());
    assert!(sd.stats.notes.iter().any(|n| n == "threads=4"), "{:?}", sd.stats.notes);
    // same instance through the implicit path: byte-identical coupling
    assert_eq!(sd.matching(), si.matching());
    assert_eq!(sd.duals, si.duals);
    coord.shutdown();
}

#[test]
fn xla_engine_through_coordinator_when_artifacts_exist() {
    let Ok(runtime) = XlaRuntime::open_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, ..Default::default() },
        Some(Arc::clone(&runtime)),
    );
    // two same-bucket jobs exercise the compile cache through batching
    let h1 = coord.submit(assignment(256, 1), 0.3, Engine::Xla).unwrap();
    let h2 = coord.submit(assignment(256, 2), 0.3, Engine::Xla).unwrap();
    for h in [h1, h2] {
        let out = h.wait().unwrap();
        let sol = out.result.expect("xla job should succeed");
        let m = sol.matching().expect("expected assignment result");
        assert!(m.is_perfect());
        assert!(sol.stats.notes.iter().any(|n| n == "bucket=256"));
        assert_eq!(out.engine_used, "xla");
    }
    coord.shutdown();
}

#[test]
fn auto_routes_large_to_xla_when_available() {
    let Ok(runtime) = XlaRuntime::open_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let coord = Coordinator::start(CoordinatorConfig::default(), Some(runtime));
    let h = coord.submit(assignment(512, 1), 0.4, Engine::Auto).unwrap();
    let out = h.wait().unwrap();
    assert_eq!(out.engine_used, "xla");
    assert!(out.result.is_ok());
    coord.shutdown();
}
