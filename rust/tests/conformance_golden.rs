//! Golden-corpus conformance: the committed fixtures pin Hungarian-exact
//! (and exact-OT) optima, and every engine is held to one contract —
//! certificates verify, and guaranteed engines land within `ε·U` of the
//! pin (the paper's Theorem 1 additive bound, as a cargo test).

use otpr::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
use otpr::data::workloads::{golden_corpus, GOLDEN_SPECS};
use otpr::exp::conformance::{run, verify_golden_pins, ConformanceConfig};

#[test]
fn golden_pins_match_exact_oracles() {
    let pins = verify_golden_pins().expect("corpus loads and oracles run");
    assert_eq!(pins.len(), GOLDEN_SPECS.len());
    for pin in pins {
        assert!(
            pin.ok(),
            "{}: fixture pins {} but the exact oracle computed {}",
            pin.name,
            pin.pinned,
            pin.computed
        );
    }
}

/// The differential satellite: on every golden instance, every
/// push-relabel-family engine's cost is within ε·U of the exact optimum
/// (Theorem 1 for assignment, Theorem 4.2 for OT), across a sweep of ε.
#[test]
fn theorem1_push_relabel_family_within_eps_of_exact() {
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default().with_paranoid(true);
    let corpus = golden_corpus().unwrap();
    for case in &corpus {
        let c_max = case.costs.max() as f64;
        let n = case.costs.na as f64;
        let engines = [
            "native-seq",
            "native-parallel",
            "native-vector",
            "native-hybrid",
            "native-seq-warm",
            "native-vector-warm",
        ];
        for engine in engines {
            for eps in [0.4, 0.2, 0.1, 0.05] {
                let (problem, exact, u) = match case.ot() {
                    Some(inst) => (Problem::Ot(inst), case.exact_cost, c_max),
                    None => (
                        Problem::Assignment(case.assignment().unwrap()),
                        case.exact_cost,
                        n * c_max,
                    ),
                };
                let sol = registry
                    .solve(engine, &config, &problem, &SolveRequest::new(eps))
                    .unwrap_or_else(|e| panic!("{} on {} failed: {e}", engine, case.name));
                let budget = eps * u;
                assert!(
                    sol.cost <= exact + budget + 1e-9,
                    "{} × {} at eps={eps}: cost {} > exact {} + {}",
                    case.name,
                    engine,
                    sol.cost,
                    exact,
                    budget
                );
            }
        }
    }
}

/// Acceptance sweep: the default conformance configuration certifies every
/// runnable cell — primal always, dual + gap for every dual-producing
/// engine — and no guaranteed engine violates its differential budget.
#[test]
fn conformance_sweep_certifies_every_engine() {
    let report = run(&ConformanceConfig::default()).unwrap();
    let failures = report.failures();
    assert!(
        failures.is_empty(),
        "{} conformance failures:\n{}",
        failures.len(),
        report.table()
    );
    assert!(
        report.errors.is_empty(),
        "native engines errored on golden cases: {:?}",
        report.errors
    );
    // Dual-producing engines must actually produce verified duals on every
    // cell they ran (the tentpole's acceptance criterion) — including the
    // vector backend and the ε-scaling warm-start engines.
    let dual_engines = [
        "native-seq",
        "native-parallel",
        "native-vector",
        "native-hybrid",
        "native-seq-warm",
        "native-vector-warm",
    ];
    for engine in dual_engines {
        let cells: Vec<_> =
            report.records.iter().filter(|r| r.engine == engine).collect();
        assert!(!cells.is_empty(), "{engine} ran no cells");
        for r in cells {
            assert!(r.cert.primal_ok, "{} × {}: primal failed", r.case_name, engine);
            assert_eq!(
                r.cert.dual_ok,
                Some(true),
                "{} × {} at eps={}: dual verdict {:?} ({:?})",
                r.case_name,
                engine,
                r.eps,
                r.cert.dual_ok,
                r.cert.detail
            );
            let gap = r.cert.gap.expect("dual-producing engines certify a gap");
            assert!(
                gap <= r.cert.bound + 1e-9,
                "{} × {}: gap {gap} > bound {}",
                r.case_name,
                engine,
                r.cert.bound
            );
        }
    }
    // Engines without duals report an absent verdict, never a false one.
    for engine in ["hungarian", "ssp-exact", "sinkhorn-native", "greedy", "lmr"] {
        for r in report.records.iter().filter(|r| r.engine == engine) {
            assert_eq!(r.cert.dual_ok, None, "{} × {engine}", r.case_name);
            assert!(r.cert.primal_ok, "{} × {engine}: {:?}", r.case_name, r.cert.detail);
        }
    }
    // XLA engines have no runtime in this environment: skipped, not failed.
    assert!(report
        .skipped
        .iter()
        .any(|(_, engine, _)| engine == "xla" || engine == "sinkhorn-xla"));
}

/// Sinkhorn contract satellite: the returned plan's marginal violation
/// stays below the solver's declared feasibility tolerance (the AWR'17
/// rounding makes plans feasible to float precision), and the attached
/// certificate reports `dual_ok = None` — absent, not failed.
#[test]
fn sinkhorn_contract_marginals_and_absent_duals() {
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let corpus = golden_corpus().unwrap();
    for case in corpus.iter().filter(|c| c.is_ot()) {
        let inst = case.ot().unwrap();
        let problem = Problem::Ot(inst.clone());
        let req = SolveRequest::new(0.2).certify(true);
        let sol = registry.solve("sinkhorn-native", &config, &problem, &req).unwrap();
        let plan = sol.plan().expect("sinkhorn returns a plan");
        // declared tolerance: post-rounding feasibility to 1e-6
        plan.check(&inst.supply, &inst.demand, 1e-6)
            .unwrap_or_else(|e| panic!("{}: marginal violation above tolerance: {e}", case.name));
        let l1: f64 = plan
            .supply_marginal()
            .iter()
            .zip(&inst.supply)
            .map(|(&got, &want)| (got - want).abs())
            .chain(
                plan.demand_marginal()
                    .iter()
                    .zip(&inst.demand)
                    .map(|(&got, &want)| (got - want).abs()),
            )
            .sum();
        assert!(l1 <= 1e-6, "{}: total marginal violation {l1}", case.name);
        let cert = sol.certificate.as_ref().unwrap();
        assert!(cert.primal_ok, "{}: {:?}", case.name, cert.detail);
        assert_eq!(cert.dual_ok, None, "{}: sinkhorn has no dual certificate", case.name);
        assert_eq!(cert.gap, None);
        assert!(cert.ok());
    }
}

/// Backend-equivalence satellite: on every golden instance, the chunked
/// and hybrid backends (at every tested thread count) and the vector
/// backend must produce **identical** matchings / plans and byte-identical
/// duals to the scalar backend — the kernel contract that makes
/// `native-parallel`, `native-hybrid`, and `native-vector` pure
/// wall-clock optimizations of `native-seq`. The
/// corpus includes non-multiple-of-8 demand widths (n = 4, 5, 6 and the
/// 3×4 OT case), so the vector backend's lane-padding path is exercised.
#[test]
fn kernel_backends_identical_on_golden_corpus() {
    let registry = SolverRegistry::with_defaults();
    let corpus = golden_corpus().unwrap();
    let mut saw_unpadded_width = false;
    for case in &corpus {
        if case.costs.na % 8 != 0 {
            saw_unpadded_width = true;
        }
        let problem = match case.ot() {
            Some(inst) => Problem::Ot(inst),
            None => Problem::Assignment(case.assignment().unwrap()),
        };
        for eps in [0.3, 0.1] {
            let req = SolveRequest::new(eps);
            let scalar = registry
                .solve("native-seq", &SolverConfig::default(), &problem, &req)
                .unwrap();
            let assert_identical = |other: &otpr::api::Solution, label: &str| {
                match (scalar.matching(), other.matching()) {
                    (Some(ms), Some(mc)) => assert_eq!(
                        ms, mc,
                        "{} eps={eps} {label}: matchings differ",
                        case.name
                    ),
                    (None, None) => assert_eq!(
                        scalar.plan().unwrap().as_slice(),
                        other.plan().unwrap().as_slice(),
                        "{} eps={eps} {label}: plans differ",
                        case.name
                    ),
                    _ => panic!("{}: coupling shapes differ across backends", case.name),
                }
                assert_eq!(
                    scalar.duals, other.duals,
                    "{} eps={eps} {label}: duals must be byte-identical",
                    case.name
                );
                assert_eq!(
                    scalar.stats.phases, other.stats.phases,
                    "{} eps={eps} {label}: phase counts differ",
                    case.name
                );
                assert_eq!(
                    scalar.stats.rounds, other.stats.rounds,
                    "{} eps={eps} {label}: round counts differ",
                    case.name
                );
                assert!(
                    (scalar.cost - other.cost).abs() < 1e-12,
                    "{} eps={eps} {label}: costs differ",
                    case.name
                );
            };
            for threads in [1usize, 2, 4, 8] {
                let config = SolverConfig::default().with_threads(threads);
                let chunked = registry
                    .solve("native-parallel", &config, &problem, &req)
                    .unwrap();
                assert_identical(&chunked, &format!("threads={threads}"));
                // the hybrid backend: the lane sweep fanned over the same
                // thread counts (the PR 7 acceptance criterion)
                let hybrid = registry
                    .solve("native-hybrid", &config, &problem, &req)
                    .unwrap();
                assert_identical(&hybrid, &format!("hybrid-threads={threads}"));
            }
            let vector = registry
                .solve("native-vector", &SolverConfig::default(), &problem, &req)
                .unwrap();
            assert_identical(&vector, "vector");
        }
    }
    assert!(saw_unpadded_width, "corpus must cover the lane-padding path");
}

#[test]
fn gap_histogram_artifact_is_consistent() {
    let cfg = ConformanceConfig {
        engines: vec!["native-seq".into(), "sinkhorn-native".into()],
        eps: vec![0.3, 0.15],
    };
    let report = run(&cfg).unwrap();
    let json = report.gap_histogram_json().to_string();
    let parsed = otpr::util::minijson::Json::parse(&json).expect("artifact is valid JSON");
    let counts: f64 = parsed
        .get("counts")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| c.as_f64().unwrap())
        .sum();
    assert_eq!(counts as usize, report.certified_gaps().len());
    // only the dual-producing engine contributes gaps
    assert!(report
        .certified_gaps()
        .iter()
        .all(|r| r.engine == "native-seq"));
    assert!(counts > 0.0, "native-seq must certify at least one gap");
}
