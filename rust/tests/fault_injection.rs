//! Chaos tests for the fault-tolerant coordinator (PR 9).
//!
//! The contract under test: **every submitted job reaches exactly one
//! terminal outcome** — Served, Degraded, Shed, or Failed — no matter
//! what panics, stalls, or dies along the way, and a deadline-pressured
//! job can trade accuracy for an answer whose certificate still
//! verifies. Faults are injected through the seeded, step-indexed
//! [`FaultPlan`], so every run here is deterministic in its seed.
//!
//! The soak's fault rate scales with `OTPR_CHAOS_JOBS` (nightly chaos CI
//! sets 512; the default 64 keeps the tier-1 wall-clock small).

use otpr::api::SolveRequest;
use otpr::coordinator::batcher::BatcherConfig;
use otpr::coordinator::{
    Coordinator, CoordinatorConfig, DegradePolicy, Engine, Fault, FaultPlan, JobKind, JobStatus,
};
use otpr::data::workloads::Workload;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn assignment(n: usize, seed: u64) -> JobKind {
    JobKind::Assignment(Workload::Fig1 { n }.assignment(seed))
}

fn ot(n: usize, seed: u64) -> JobKind {
    JobKind::Ot(Workload::Fig1 { n }.ot_with_random_masses(seed))
}

/// The acceptance soak: a seeded storm of worker panics, transient
/// errors, and latency injections over a mixed job stream. Every handle
/// must resolve (a hang fails the test via the harness timeout), the
/// status taxonomy must account for every job exactly once, and the
/// queue-depth gauge must drain to zero.
#[test]
fn soak_every_job_reaches_exactly_one_terminal_outcome() {
    let jobs: u64 = std::env::var("OTPR_CHAOS_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    // fault counts scale with the soak size: ~5% panics, ~9% transients,
    // ~6% delays, all on disjoint jobs
    let plan = FaultPlan::seeded(
        9,
        jobs,
        (jobs / 20).max(2) as usize,
        (jobs / 11).max(3) as usize,
        (jobs / 16).max(2) as usize,
        Duration::from_millis(3),
    );
    let scheduled = plan.len();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 3,
            restart_budget: jobs as u32, // panics must never strand the pool mid-soak
            // Batch composition is scheduling-dependent: an innocent job can
            // be swept into retry by a panic-faulted batch-mate more than
            // once, so the retry budget (like the restart budget) must be
            // generous enough that only fault-plan exhaustion is terminal.
            max_retries: jobs as u32,
            default_deadline: Some(Duration::from_secs(60)),
            faults: Some(Arc::new(plan)),
            ..Default::default()
        },
        None,
    );
    let handles: Vec<_> = (0..jobs)
        .map(|i| {
            let kind = if i % 4 == 0 { ot(10, i) } else { assignment(12, i) };
            coord.submit(kind, 0.3, Engine::NativeSeq).unwrap()
        })
        .collect();
    let (mut served, mut degraded, mut shed, mut failed) = (0u64, 0u64, 0u64, 0u64);
    for h in handles {
        let out = h.wait().expect("every handle resolves — no lost replies");
        match out.status {
            JobStatus::Served => served += 1,
            JobStatus::Degraded { .. } => degraded += 1,
            JobStatus::Shed { .. } => shed += 1,
            JobStatus::Failed { .. } => failed += 1,
        }
    }
    assert_eq!(served + degraded + shed + failed, jobs, "status taxonomy covers every job");
    // a 60s tenant deadline and a generous retry budget absorb the whole
    // storm: injected faults retry into success, nothing fails or sheds
    assert_eq!(failed, 0, "transients and panics must retry into success");
    assert_eq!(shed, 0, "nothing expires under a 60s deadline");
    let metrics = coord.metrics.clone();
    coord.shutdown();
    assert!(metrics.worker_panics.load(Ordering::Relaxed) >= 1, "the storm included panics");
    assert!(metrics.retried.load(Ordering::Relaxed) >= 1, "injured jobs re-entered the queue");
    assert_eq!(
        metrics.worker_panics.load(Ordering::Relaxed),
        metrics.worker_restarts.load(Ordering::Relaxed),
        "under budget, every panicked worker is replaced"
    );
    assert_eq!(metrics.completed.load(Ordering::Relaxed), jobs);
    assert_eq!(metrics.queue_depth(), 0, "the saturation gauge drains to zero");
    assert!(scheduled > 0, "the plan actually scheduled faults");
}

/// Supervision isolates a panic to its own batch: with one job per batch
/// (max_batch = 1) and two workers, a panic-faulted job's siblings keep
/// their worker and serve untouched, while the casualty retries on the
/// respawned worker. This pins the poisoned-receiver recovery path in
/// `worker_loop` — the surviving worker keeps draining the shared
/// receiver its sibling panicked around.
#[test]
fn sibling_jobs_survive_a_worker_panic_untouched() {
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
            faults: Some(Arc::new(FaultPlan::new().panic_at(1))),
            ..Default::default()
        },
        None,
    );
    let victim = coord.submit(assignment(12, 0), 0.3, Engine::NativeSeq).unwrap();
    let siblings: Vec<_> = (1..6)
        .map(|i| coord.submit(assignment(12, i), 0.3, Engine::NativeSeq).unwrap())
        .collect();
    for h in siblings {
        let out = h.wait().unwrap();
        assert_eq!(out.status, JobStatus::Served, "siblings never see the panic");
        assert!(out.result.is_ok());
    }
    let out = victim.wait().unwrap();
    assert_eq!(out.status, JobStatus::Served, "the victim's retry lands: {:?}", out.result);
    let metrics = coord.metrics.clone();
    coord.shutdown();
    assert_eq!(metrics.worker_panics.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.worker_restarts.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.queue_depth(), 0);
}

/// The degradation acceptance criterion: an OT job whose wall-clock
/// budget cancels the solve resolves — under [`DegradePolicy`] — to a
/// coarser-ε answer from the warm ladder, with a certificate attached
/// that verifies.
#[test]
fn deadline_pressured_ot_job_degrades_with_a_verified_certificate() {
    let eps = 0.2;
    let coord = Coordinator::start(
        CoordinatorConfig {
            degrade: DegradePolicy {
                enabled: true,
                grace: Duration::from_secs(30), // the re-solve itself must not be rushed
                ..Default::default()
            },
            ..Default::default()
        },
        None,
    );
    // a zero budget cancels the first solve before any phase completes
    let rushed = SolveRequest::new(eps).with_budget(Duration::ZERO);
    let h = coord.submit_request(ot(20, 7), rushed, Engine::NativeSeq).unwrap();
    let out = h.wait().unwrap();
    let JobStatus::Degraded { eps: got } = out.status else {
        panic!("expected a degraded answer, got {:?}", out.status);
    };
    assert!(got > eps, "degraded ε {got} must be coarser than the requested {eps}");
    let sol = out.result.expect("a degraded answer is still an answer");
    assert!(!sol.is_cancelled(), "the grace re-solve ran to completion");
    let cert = sol.certificate.as_ref().expect("degraded answers carry their certificate");
    assert!(cert.primal_ok, "certificate: {}", cert.summary());
    assert!(cert.gap_ok(), "certificate: {}", cert.summary());
    assert!(cert.ok(), "certificate: {}", cert.summary());
    let metrics = coord.metrics.clone();
    coord.shutdown();
    assert_eq!(metrics.degraded.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.queue_depth(), 0);
}

/// A tenant default deadline of zero sheds everything at dispatch with a
/// retry hint — the load-shedding contract a caller can program against.
#[test]
fn expired_tenant_deadline_sheds_with_a_retry_hint() {
    let coord = Coordinator::start(
        CoordinatorConfig { default_deadline: Some(Duration::ZERO), ..Default::default() },
        None,
    );
    let handles: Vec<_> =
        (0..4).map(|i| coord.submit(assignment(10, i), 0.3, Engine::NativeSeq).unwrap()).collect();
    for h in handles {
        let out = h.wait().unwrap();
        let JobStatus::Shed { retry_after } = out.status else {
            panic!("expected shed, got {:?}", out.status);
        };
        assert!(retry_after > Duration::ZERO, "the hint tells the caller when to come back");
        let err = out.result.expect_err("shed jobs carry no solution");
        assert!(err.contains("shed"), "{err}");
    }
    let metrics = coord.metrics.clone();
    coord.shutdown();
    assert_eq!(metrics.shed.load(Ordering::Relaxed), 4);
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 0);
    assert_eq!(metrics.failed.load(Ordering::Relaxed), 0, "shed is not failure");
    assert_eq!(metrics.queue_depth(), 0);
}

/// Retry-budget exhaustion is a terminal, attributed failure: a job hit
/// by a transient fault on every attempt reports `Failed` with the full
/// attempt count, and the metrics show each re-entry.
#[test]
fn transient_storm_exhausts_the_retry_budget_terminally() {
    let plan = FaultPlan::new()
        .at_attempt(1, 0, Fault::Transient)
        .at_attempt(1, 1, Fault::Transient);
    let coord = Coordinator::start(
        CoordinatorConfig { max_retries: 1, faults: Some(Arc::new(plan)), ..Default::default() },
        None,
    );
    let h = coord.submit(assignment(10, 1), 0.3, Engine::NativeSeq).unwrap();
    let out = h.wait().unwrap();
    assert!(
        matches!(out.status, JobStatus::Failed { attempts: 2 }),
        "one execution + one retry, both transient: {:?}",
        out.status
    );
    // the coordinator keeps serving after the casualty
    let h2 = coord.submit(assignment(10, 2), 0.3, Engine::NativeSeq).unwrap();
    assert_eq!(h2.wait().unwrap().status, JobStatus::Served);
    let metrics = coord.metrics.clone();
    coord.shutdown();
    assert_eq!(metrics.retried.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.failed.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.queue_depth(), 0);
}
