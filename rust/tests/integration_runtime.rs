//! Runtime integration over the real AOT artifacts (`make artifacts` must
//! have run; tests skip gracefully otherwise). Verifies the full
//! L1(Pallas)→L2(JAX)→HLO→PJRT→L3 chain: numerics of each artifact against
//! the native implementations, then whole solves.

use otpr::core::{AssignmentInstance, OtInstance};
use otpr::data::synthetic;
use otpr::data::workloads::Workload;
use otpr::runtime::client::{download_f32, download_i32, run1};
use otpr::runtime::{XlaAssignment, XlaRuntime, XlaSinkhorn};
use otpr::solvers::hungarian::Hungarian;
use otpr::solvers::push_relabel::PushRelabel;
use otpr::solvers::{AssignmentSolver, OtSolver};
use otpr::util::rng::Pcg32;
use std::sync::Arc;

fn runtime() -> Option<Arc<XlaRuntime>> {
    match XlaRuntime::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping runtime tests: {e}");
            None
        }
    }
}

#[test]
fn cost_euclid_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let n = 256;
    let mut rng = Pcg32::new(1);
    let pts_b = synthetic::uniform_points(n, &mut rng);
    let pts_a = synthetic::uniform_points(n, &mut rng);
    let native = synthetic::euclidean_costs(&pts_b, &pts_a);
    let fb = synthetic::points_to_f32(&pts_b);
    let fa = synthetic::points_to_f32(&pts_a);
    let dev = rt
        .call(move |ctx| {
            let fb = ctx.upload_f32(&fb, &[n, 2])?;
            let fa = ctx.upload_f32(&fa, &[n, 2])?;
            let exe = ctx.executable("cost_euclid", n)?;
            let out = run1(&exe, &[&fb, &fa])?;
            download_f32(&out, n * n)
        })
        .unwrap();
    for (i, (&d, &h)) in dev.iter().zip(native.as_slice()).enumerate() {
        assert!((d - h).abs() < 1e-5, "mismatch at {i}: {d} vs {h}");
    }
}

#[test]
fn quantize_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let n = 256;
    let costs = Workload::Fig1 { n }.costs(2);
    let q_native = otpr::core::QuantizedCosts::new(&costs, 0.1);
    let inv = 1.0 / q_native.eps_abs;
    let data: Vec<f32> = costs.as_slice().to_vec();
    let dev = rt
        .call(move |ctx| {
            let c = ctx.upload_f32(&data, &[n, n])?;
            let inv_b = ctx.upload_f32(&[inv as f32], &[1])?;
            let exe = ctx.executable("quantize", n)?;
            let out = run1(&exe, &[&c, &inv_b])?;
            download_i32(&out, n * n)
        })
        .unwrap();
    let mut diffs = 0;
    for (d, h) in dev.iter().zip(&q_native.cq) {
        // f32-vs-f64 floor boundary flips are possible but must be rare
        if d != h {
            diffs += 1;
        }
    }
    assert!(
        diffs as f64 <= 0.001 * (n * n) as f64,
        "{diffs} quantization mismatches out of {}",
        n * n
    );
}

#[test]
fn matrix_max_artifact() {
    let Some(rt) = runtime() else { return };
    let n = 256;
    let costs = Workload::Fig1 { n }.costs(3);
    let native_max = costs.max();
    let data: Vec<f32> = costs.as_slice().to_vec();
    let dev = rt
        .call(move |ctx| {
            let c = ctx.upload_f32(&data, &[n, n])?;
            let exe = ctx.executable("matrix_max", n)?;
            let out = run1(&exe, &[&c])?;
            download_f32(&out, 1)
        })
        .unwrap();
    assert!((dev[0] - native_max).abs() < 1e-6);
}

#[test]
fn xla_assignment_guarantee_exact_bucket() {
    let Some(rt) = runtime() else { return };
    let n = 256;
    let inst = Workload::Fig1 { n }.assignment(4);
    let exact = Hungarian.solve_assignment(&inst, 0.0).unwrap();
    let c_max = inst.costs.max() as f64;
    let eps = 0.05;
    let sol = XlaAssignment::new(rt).solve_costs(&inst, eps).unwrap();
    assert!(sol.matching.is_perfect());
    assert!(
        sol.cost <= exact.cost + 3.0 * eps * n as f64 * c_max + 1e-6,
        "xla {} vs exact {}",
        sol.cost,
        exact.cost
    );
}

#[test]
fn xla_assignment_padded_bucket() {
    let Some(rt) = runtime() else { return };
    let n = 300; // pads to 512
    let inst = Workload::Fig1 { n }.assignment(5);
    let exact = Hungarian.solve_assignment(&inst, 0.0).unwrap();
    let c_max = inst.costs.max() as f64;
    let eps = 0.1;
    let sol = XlaAssignment::new(rt).solve_costs(&inst, eps).unwrap();
    assert!(sol.matching.is_perfect());
    assert_eq!(sol.matching.nb(), n);
    assert!(sol.cost <= exact.cost + 3.0 * eps * n as f64 * c_max + 1e-6);
}

#[test]
fn xla_points_path_agrees_with_native_path() {
    let Some(rt) = runtime() else { return };
    let n = 256;
    let mut rng_a = Pcg32::with_stream(6, 1);
    let mut rng_b = Pcg32::with_stream(6, 2);
    let pts_a = synthetic::uniform_points(n, &mut rng_a);
    let pts_b = synthetic::uniform_points(n, &mut rng_b);
    let costs = synthetic::euclidean_costs(&pts_b, &pts_a);
    let inst = AssignmentInstance::new(costs).unwrap();
    let eps = 0.1;
    let solver = XlaAssignment::new(rt);
    let via_points = solver
        .solve_points(
            &synthetic::points_to_f32(&pts_b),
            &synthetic::points_to_f32(&pts_a),
            &inst,
            eps,
        )
        .unwrap();
    let native = PushRelabel::new().solve_with_param(&inst, eps).unwrap();
    let c_max = inst.costs.max() as f64;
    let budget = 3.0 * eps * n as f64 * c_max;
    // both are valid 3ε approximations of the same instance
    assert!(via_points.cost <= native.cost + budget + 1e-6);
    assert!(native.cost <= via_points.cost + budget + 1e-6);
}

#[test]
fn xla_sinkhorn_feasible_and_accurate() {
    let Some(rt) = runtime() else { return };
    let n = 256;
    let inst = OtInstance::uniform(Workload::Fig1 { n }.costs(7)).unwrap();
    let eps = 0.25;
    let sol = XlaSinkhorn::new(rt).solve_ot(&inst, eps).unwrap();
    sol.plan.check(&inst.supply, &inst.demand, 1e-5).unwrap();
    // uniform OT optimum = assignment optimum / n
    let (_, exact_cost, _, _) = otpr::solvers::hungarian::solve_exact(&inst.costs).unwrap();
    let exact = exact_cost / n as f64;
    let c_max = inst.costs.max() as f64;
    assert!(sol.cost <= exact + eps * c_max + 1e-6);
    assert!(sol.cost >= exact - 1e-6);
}

#[test]
fn compile_cache_reused_across_solves() {
    let Some(rt) = runtime() else { return };
    let inst = Workload::Fig1 { n: 256 }.assignment(8);
    let solver = XlaAssignment::new(Arc::clone(&rt));
    let t1 = std::time::Instant::now();
    let _ = solver.solve_costs(&inst, 0.2).unwrap();
    let first = t1.elapsed();
    let t2 = std::time::Instant::now();
    let _ = solver.solve_costs(&inst, 0.2).unwrap();
    let second = t2.elapsed();
    // second solve skips HLO parse+compile; expect a visible speedup
    assert!(second < first, "cache produced no speedup: {first:?} vs {second:?}");
    let cached = rt.call(|ctx| Ok(ctx.cached_count())).unwrap();
    assert!(cached >= 2, "expected quantize+phase_step cached, got {cached}");
}
